//! Rendezvous (highest-random-weight) shard placement for the federation tier.
//!
//! Every enrolled identity key is owned by the `replication` units with the
//! highest rendezvous weight `hrw_weight(unit_uid, key)`. The scheme needs no
//! central directory and is stable under membership change: adding or removing
//! one unit only reassigns the keys whose top-RF set that unit enters or
//! leaves (~RF/N of the corpus), never a full reshuffle. Routing (which owner
//! actually answers a probe) is a separate, liveness-aware choice so that a
//! detached unit's keys fall through to the next-ranked live replica without
//! moving any data.

/// SplitMix64 finalizer: the avalanche core used to turn (unit, key) into a
/// uniform rendezvous weight. Deterministic across platforms.
#[inline]
fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Rendezvous weight of `unit_uid` for `key`. Higher wins ownership.
#[inline]
pub fn hrw_weight(unit_uid: u64, key: u64) -> u64 {
    mix64(unit_uid ^ key.rotate_left(32) ^ 0x9e37_79b9_7f4a_7c15)
}

/// FNV-1a over an identity string: the stable placement key for an id.
pub fn placement_key(id: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in id.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Membership + liveness view of the federation rack.
#[derive(Debug, Clone)]
pub struct ShardMap {
    /// Unit uids in attach order; index into this vec is the unit index used
    /// everywhere else in the federation tier.
    units: Vec<u64>,
    live: Vec<bool>,
    replication: usize,
}

impl ShardMap {
    /// Build a map over `units` (uids must be unique) with the given
    /// replication factor, clamped to the unit count.
    pub fn new(units: &[u64], replication: usize) -> Self {
        assert!(!units.is_empty(), "federation needs at least one unit");
        for (i, u) in units.iter().enumerate() {
            assert!(!units[..i].contains(u), "duplicate unit uid {u:#x}");
        }
        let rf = replication.max(1).min(units.len());
        ShardMap { units: units.to_vec(), live: vec![true; units.len()], replication: rf }
    }

    pub fn units(&self) -> &[u64] {
        &self.units
    }

    pub fn replication(&self) -> usize {
        self.replication
    }

    pub fn len(&self) -> usize {
        self.units.len()
    }

    pub fn is_empty(&self) -> bool {
        self.units.is_empty()
    }

    pub fn is_live(&self, unit: usize) -> bool {
        self.live.get(unit).copied().unwrap_or(false)
    }

    pub fn live_count(&self) -> usize {
        self.live.iter().filter(|l| **l).count()
    }

    /// Mark a unit live (re-attach) or dead (detach). Placement is unchanged;
    /// only routing decisions see liveness.
    pub fn set_live(&mut self, unit: usize, live: bool) {
        self.live[unit] = live;
    }

    /// Expand the rack with a new unit (live). Returns its unit index. The
    /// replication factor is re-clamped in case the rack was smaller than the
    /// requested RF at construction.
    pub fn add_unit(&mut self, uid: u64, requested_rf: usize) -> usize {
        assert!(!self.units.contains(&uid), "duplicate unit uid {uid:#x}");
        self.units.push(uid);
        self.live.push(true);
        self.replication = requested_rf.max(self.replication).min(self.units.len());
        self.units.len() - 1
    }

    /// The `replication` owner unit indexes for `key`, ranked best-first by
    /// (rendezvous weight desc, uid asc). Liveness is ignored: ownership is a
    /// placement fact, routing handles failures.
    pub fn owners(&self, key: u64) -> Vec<usize> {
        let mut ranked: Vec<(u64, u64, usize)> = self
            .units
            .iter()
            .enumerate()
            .map(|(i, &uid)| (hrw_weight(uid, key), uid, i))
            .collect();
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        ranked.truncate(self.replication);
        ranked.into_iter().map(|(_, _, i)| i).collect()
    }

    /// Owner set as it stood *before* unit `skip` joined: the top-RF ranked
    /// units with `skip` filtered out. Used while a rack expansion is still
    /// draining, so fresh enrolls keep full replication on units that can
    /// already hold data.
    pub fn owners_excluding(&self, key: u64, skip: usize) -> Vec<usize> {
        let mut ranked: Vec<(u64, u64, usize)> = self
            .units
            .iter()
            .enumerate()
            .filter(|&(i, _)| i != skip)
            .map(|(i, &uid)| (hrw_weight(uid, key), uid, i))
            .collect();
        ranked.sort_by(|a, b| b.0.cmp(&a.0).then(a.1.cmp(&b.1)));
        ranked.truncate(self.replication.min(ranked.len().max(1)));
        ranked.into_iter().map(|(_, _, i)| i).collect()
    }

    /// Highest-weight live unit among `candidates` for `key` — the routing
    /// decision. `None` when every candidate replica is down.
    pub fn best_live(&self, key: u64, candidates: &[usize]) -> Option<usize> {
        candidates
            .iter()
            .copied()
            .filter(|&u| self.is_live(u))
            .max_by(|&a, &b| {
                hrw_weight(self.units[a], key)
                    .cmp(&hrw_weight(self.units[b], key))
                    .then(self.units[b].cmp(&self.units[a]))
            })
    }

    /// Routing without an explicit resident set: best live unit among the
    /// placement owners of `key`.
    pub fn route(&self, key: u64) -> Option<usize> {
        let owners = self.owners(key);
        self.best_live(key, &owners)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(n: usize) -> Vec<u64> {
        (0..n).map(|i| placement_key(&format!("id{i}"))).collect()
    }

    #[test]
    fn owners_are_deterministic_distinct_and_rf_sized() {
        let map = ShardMap::new(&[11, 22, 33, 44], 2);
        for key in keys(500) {
            let o1 = map.owners(key);
            let o2 = map.owners(key);
            assert_eq!(o1, o2);
            assert_eq!(o1.len(), 2);
            assert_ne!(o1[0], o1[1]);
        }
    }

    #[test]
    fn replication_clamps_to_unit_count() {
        let map = ShardMap::new(&[7], 3);
        assert_eq!(map.replication(), 1);
        assert_eq!(map.owners(99).len(), 1);
    }

    #[test]
    fn detach_routes_to_next_ranked_replica_and_reattach_restores() {
        let mut map = ShardMap::new(&[11, 22, 33, 44], 2);
        let key = placement_key("id42");
        let owners = map.owners(key);
        let primary = map.route(key).unwrap();
        assert_eq!(primary, owners[0]);
        map.set_live(primary, false);
        let fallback = map.route(key).unwrap();
        assert_eq!(fallback, owners[1]);
        map.set_live(primary, true);
        assert_eq!(map.route(key).unwrap(), primary);
    }

    #[test]
    fn placement_is_stable_under_expansion() {
        // Adding one unit to an N-unit rack must move only the keys whose
        // top-RF set the new unit enters: ~RF/(N+1) of owner sets change and
        // ~1/(N+1) of primaries move. Gate at 2x the expectation.
        let n = 4usize;
        let ks = keys(20_000);
        let base = ShardMap::new(&[11, 22, 33, 44], 2);
        let before_owners: Vec<Vec<usize>> = ks.iter().map(|&k| base.owners(k)).collect();
        let before_primary: Vec<usize> = ks.iter().map(|&k| base.route(k).unwrap()).collect();

        let mut grown = base.clone();
        let new_unit = grown.add_unit(55, 2);
        let mut owner_changed = 0usize;
        let mut primary_moved = 0usize;
        for (i, &k) in ks.iter().enumerate() {
            let now = grown.owners(k);
            if now != before_owners[i] {
                owner_changed += 1;
                // Every change must be the new unit entering the set.
                assert!(now.contains(&new_unit), "owner churn unrelated to the added unit");
            }
            if grown.route(k).unwrap() != before_primary[i] {
                primary_moved += 1;
            }
        }
        let total = ks.len() as f64;
        let owner_frac = owner_changed as f64 / total;
        let primary_frac = primary_moved as f64 / total;
        let rf = 2.0;
        let n1 = (n + 1) as f64;
        assert!(owner_frac > 0.0, "expansion moved nothing; hashing is degenerate");
        assert!(
            owner_frac < 2.0 * rf / n1,
            "owner churn {owner_frac:.3} exceeds 2x the rendezvous expectation {:.3}",
            rf / n1
        );
        assert!(
            primary_frac < 2.0 / n1,
            "primary churn {primary_frac:.3} exceeds 2x the rendezvous expectation {:.3}",
            1.0 / n1
        );
    }

    #[test]
    fn keys_spread_roughly_evenly() {
        let map = ShardMap::new(&[1, 2, 3, 4], 2);
        let mut per_unit = [0usize; 4];
        let ks = keys(40_000);
        for &k in &ks {
            per_unit[map.route(k).unwrap()] += 1;
        }
        let expect = ks.len() / 4;
        for (u, &c) in per_unit.iter().enumerate() {
            assert!(
                c > expect / 2 && c < expect * 2,
                "unit {u} holds {c} primaries, expected ~{expect}"
            );
        }
    }
}
