//! The serving session: a deterministic virtual-time loop that multiplexes
//! admitted requests onto the match engine and the accelerator pipeline.
//!
//! Two servers sit behind the admission controller:
//!
//! * the **match server** — `Identify` requests are coalesced (up to the
//!   configured batch) into one [`GalleryIndex::top_k_batch`] probe pass;
//!   the virtual service time of a pass is the calibrated gallery-scan
//!   cost, amortized across the batch exactly as the SoA batch kernel
//!   amortizes its row blocks;
//! * the **inference pipeline** — `Enroll`/`ArtifactRun` requests batch
//!   onto the face-stack cartridges, chained through each stage's FIFO
//!   timeline (the same `Resource` substrate the dispatch engine books),
//!   bounded by a [`CreditFlow`] window.  The pipeline's capacity is
//!   calibrated at session start by an actual
//!   [`Orchestrator::run_pipelined_engine`] run with the same batch and
//!   window, so offered load factors are expressed against what the
//!   engine really sustains.
//!
//! Hot-plug is survived, not ignored: a scripted detach cancels the
//! pipeline's in-flight batches; the [`HealthMonitor`] sweep (driven from
//! the periodic serve tick) detects the dead cartridge and **evicts** —
//! cancelled requests are requeued *exactly once* (a second eviction sheds
//! them as [`ShedReason::Evicted`]).  A re-attach before the sweep fires
//! requeues immediately and re-registers the heartbeat, so the recovered
//! cartridge never alerts on its stale pre-detach heartbeat.
//!
//! Everything runs in virtual microseconds off one completion queue: the
//! same seed yields the same terminal outcome for every request, which is
//! what makes `BENCH_serve.json` bit-identical across runs.
//!
//! **Serving from sealed media**: with [`ServeConfig::image`] set, the
//! session mounts the cartridge image through a [`MountSupervisor`]
//! (MAC-verified, fail-closed) and resolves Identify traffic against the
//! image's streaming-decoded [`GalleryIndex`] — the sealed cartridge *is*
//! the data plane, exactly the CHAMP premise.  A hot-swap of the storage
//! bay ([`STORAGE_SLOT`]) unmounts mid-run: identify falls back to the
//! in-memory index (enroll overlay) without dropping a request, and a
//! re-attach swaps the mounted snapshot back in atomically.

use std::collections::{BTreeMap, BTreeSet};
use std::path::PathBuf;
use std::sync::Arc;

use crate::biometric::index::GalleryIndex;
use crate::biometric::ivf::{IvfIndex, DEFAULT_NPROBE};
use crate::bus::clock::Resource;
use crate::bus::hotplug::{HotplugEvent, HotplugKind};
use crate::bus::topology::SlotId;
use crate::bus::usb3::BusProfile;
use crate::coordinator::completion::CompletionQueue;
use crate::coordinator::engine::EngineConfig;
use crate::coordinator::flow::CreditFlow;
use crate::coordinator::health::Alert;
use crate::coordinator::scheduler::Orchestrator;
use crate::crypto::seal::SealKey;
use crate::device::caps::CapDescriptor;
use crate::device::timing::{stream_handoff_us, DeviceProfile};
use crate::device::{Cartridge, DeviceKind};
use crate::obs::detect::TickSample;
use crate::obs::{
    AlertKind, AnomalyAlert, AnomalyEngine, EventKind, FlightRecorder, FlightTrigger, SeriesId,
    SloBudget, Stage, TraceId, TraceRecorder, TraceSnapshot,
};
use crate::power::{PowerModel, PowerReport};
use crate::util::rng::Rng;
use crate::vdisk::{fold_records, EnrollJournal, JournalRecord, MountEvent, MountSupervisor};
use crate::workload::video::VideoSource;

use super::admission::{
    Admission, AdmissionController, AdmissionGovernor, GovernorConfig, ShedReason,
};
use super::slo::{ClassOutcome, SloTracker, TenantOutcome};
use super::traffic::{self, MissionProfile, Request, RequestKind};

/// Health/expiry tick period (matches the orchestrator's heartbeat
/// interval: 5 missed ticks = dead).
const TICK_US: u64 = 100_000;

/// The storage bay: hot-plug events on this slot mount/unmount the sealed
/// gallery image instead of touching the inference chain (slots 0..2).
pub const STORAGE_SLOT: u8 = 3;

/// Cartridge uid the serving session registers its media under.
const STORAGE_MEDIA_UID: u64 = 0x5700;

/// Result-return wire time appended to a pipeline chain, virtual us.
const TAIL_US: u64 = 200;

/// Virtual cost of one gallery pass scoring `count` probes: a fixed
/// stream-the-matrix term plus a per-probe term (the SoA batch kernel
/// shares the row traffic across the batch, so probes amortize).
pub fn scan_pass_us(rows: usize, dim: usize, count: usize) -> u64 {
    let cells = rows.max(1) as u64 * dim.max(1) as u64;
    let fixed = cells / 2_000 + 200;
    let per_probe = cells / 4_000 + 50;
    fixed + per_probe * count.max(1) as u64
}

/// Widest `nprobe` for this pass: doubling up from [`DEFAULT_NPROBE`]
/// while the widened pass still costs at most a quarter of the tightest
/// deadline slack, capped at `nlist` (at which the tier's own fallback
/// makes the search exact).  Never returns below the default, so the
/// recall floor committed by the default probe width holds for every
/// request ever served.
pub fn boosted_nprobe(
    tier: &IvfIndex,
    dim: usize,
    batch: usize,
    overlay_rows: usize,
    slack_us: u64,
) -> usize {
    let mut np = DEFAULT_NPROBE;
    loop {
        let next = np * 2;
        if next > tier.nlist() {
            break;
        }
        let cost = scan_pass_us(tier.expected_scan_rows(next) + overlay_rows, dim, batch);
        if cost.saturating_mul(4) > slack_us {
            break;
        }
        np = next;
    }
    np
}

/// Score-merge two ranked hit lists (mounted pass + overlay scan) into
/// one top-k.  Row numbers keep their source index's numbering — the
/// serve loop treats them as opaque; identity resolution goes through
/// [`ServeSession::verify_replay`]'s merged rank-1 path.
fn merge_hits(
    mut a: Vec<(usize, f32)>,
    b: Vec<(usize, f32)>,
    k: usize,
) -> Vec<(usize, f32)> {
    a.extend(b);
    a.sort_by(|x, y| y.1.total_cmp(&x.1));
    a.truncate(k);
    a
}

/// Serving-run configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub profile: MissionProfile,
    pub seed: u64,
    /// Offered requests for the run.
    pub requests: u64,
    /// Offered load as a multiple of calibrated system capacity.
    pub overload: f64,
    /// Max requests coalesced per dispatch (both servers).
    pub batch: u32,
    /// In-flight pipeline batches allowed (credit window).
    pub window: u32,
    /// Enrolled identities at session start (ignored when serving from a
    /// mounted image — the image's gallery is the population).
    pub gallery: usize,
    pub dim: usize,
    /// Top-k retrieved per identify probe.
    pub k: usize,
    /// Sealed cartridge image to serve Identify traffic from.  None = the
    /// in-memory index only (the pre-vdisk behavior).
    pub image: Option<PathBuf>,
    /// Seal passphrase for `image`.
    pub image_key: String,
    /// Durable enrollment journal (requires `image`).  Every acked
    /// `Enroll` is sealed and synced to this file *before* the ack; at
    /// session start, frames from a previous run (or crash) are replayed
    /// into the overlay so the acked set survives a power cycle.
    pub journal: Option<PathBuf>,
    /// Record a causal trace of the run (admission → queue → dispatch →
    /// bus grant → compute → unseal).  Off = the no-op recorder path; the
    /// outcome's reports are bit-identical either way.
    pub trace: bool,
    /// Arm the black-box flight recorder: a bounded ring of the most
    /// recent spans/events/metric samples, sealed and dumped to this
    /// sidecar path on the *first* trigger (shed-rate spike, deadline
    /// miss burst, eviction, journal stall, panic).  None = the no-op
    /// recorder path; an armed-but-never-triggered run's reports are
    /// bit-identical to off.
    pub flight: Option<PathBuf>,
    /// Close the loop: let the anomaly engine's burn level scale the
    /// admission token-bucket refill down under sustained burn (and back
    /// up hysteretically once it clears).
    pub governor: bool,
    /// Background journal compaction: at a health tick where the journal
    /// holds at least this many sealed frames, fold it into the image in
    /// place and rebind (0 = never compact mid-run).
    pub compact_threshold: u64,
}

impl ServeConfig {
    pub fn new(profile: MissionProfile) -> Self {
        ServeConfig {
            profile,
            seed: 7,
            requests: 200,
            overload: 2.0,
            batch: 2,
            window: 2,
            gallery: 10_000,
            dim: 128,
            k: 10,
            image: None,
            image_key: "champ-dev-key".to_string(),
            journal: None,
            trace: false,
            flight: None,
            governor: false,
            compact_threshold: 0,
        }
    }
}

/// One dispatch decision, for EDF-order verification.
#[derive(Debug, Clone, Copy)]
pub struct DispatchEntry {
    pub class: u8,
    pub priority: u8,
    pub at_us: u64,
    pub deadline_us: u64,
    pub arrival_us: u64,
}

/// What a serving run produced.
#[derive(Debug, Clone)]
pub struct ServeOutcome {
    pub classes: Vec<ClassOutcome>,
    /// Per-tenant fairness rows; counters are read back from the metrics
    /// registry (schema-v2 report rows).
    pub tenants: Vec<TenantOutcome>,
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    pub requeued: u64,
    /// First offer → last terminal outcome, virtual us.
    pub elapsed_us: u64,
    pub power: PowerReport,
    pub alerts: Vec<Alert>,
    pub dispatch_log: Vec<DispatchEntry>,
    /// Calibrated capacity (overload 1.0 offered rate), requests/s.
    pub capacity_rps: f64,
    pub offered_rps: f64,
    /// Identify requests answered through the mounted ANN tier (0 when
    /// the image carries no IVF extent or the media is out).
    pub ann_served: u64,
    /// Identify requests whose pass widened `nprobe` beyond the default
    /// because every coalesced request had deadline headroom.
    pub ann_boosted: u64,
    /// Enrollments durably journaled before their ack (0 without a
    /// journal configured).
    pub journal_appends: u64,
    /// Journal records recovered and replayed into the overlay at
    /// session start (a previous run's acked enrollments).
    pub journal_recovered: u64,
    /// Exactly-once terminal accounting held for every class.
    pub accounting_ok: bool,
    /// Mount lifecycle of the sealed gallery media (empty when serving
    /// purely in-memory).
    pub media_events: Vec<MountEvent>,
    /// The causal trace + metrics snapshot (None unless `cfg.trace`).
    pub trace: Option<TraceSnapshot>,
    /// Streaming anomaly alerts raised during the run (empty unless the
    /// detector engine ran: flight armed or governor on).
    pub anomaly_alerts: Vec<AnomalyAlert>,
    /// The sealed flight dump written this run (first trigger wins; None
    /// when unarmed or never triggered).
    pub flight_dump: Option<PathBuf>,
    /// Lowest token-bucket refill scale the governor reached (1.0 when
    /// the governor is off or never engaged).
    pub governor_min_scale: f64,
    /// Background journal compactions folded during the run.
    pub compactions: u64,
    /// Completions past their deadline, summed over classes.
    pub deadline_misses: u64,
    /// Sheds *after* admission (expired + evicted + queue-full + journal
    /// stall) — the waste the governor exists to reduce, as opposed to
    /// its own rate-limited sheds at the front door.
    pub post_admission_sheds: u64,
}

#[derive(Debug, Clone)]
struct InferBatch {
    reqs: Vec<Request>,
}

#[derive(Debug, Clone)]
struct MatchBatch {
    id: u64,
    reqs: Vec<Request>,
}

#[derive(Debug, Clone, Copy)]
enum SEv {
    Arrival(u32),
    InferDone(u64),
    MatchDone(u64),
    Hotplug(u32),
    HealthTick,
}

/// A serving session over one mission profile.
pub struct ServeSession {
    cfg: ServeConfig,
    o: Orchestrator,
    /// Inference chain, slot order (slot i holds `stage_uids[i]`).
    stage_uids: Vec<u64>,
    /// In-memory index: the whole population when no media is configured,
    /// otherwise the enroll overlay + detach fallback.
    index: GalleryIndex,
    /// The storage bay (media registry + verified mounts), when serving
    /// from a sealed image.
    mounts: Option<MountSupervisor>,
    /// Snapshot of the mounted image's gallery; swapped atomically on
    /// hot-swap (None while the media is out).
    mounted_index: Option<Arc<GalleryIndex>>,
    /// The mounted image's ANN tier, if it carries one; rides the same
    /// swap lifecycle as `mounted_index`.
    mounted_ivf: Option<Arc<IvfIndex>>,
    /// Write-ahead enrollment journal: an `Enroll` acks only after its
    /// sealed frame is synced here (None without [`ServeConfig::journal`]).
    journal: Option<EnrollJournal>,
    /// Records recovered from the journal at open (already folded into
    /// the overlay), kept for [`ServeSession::verify_replay`].
    recovered: Vec<JournalRecord>,
    match_res: Resource,
    flow: CreditFlow,
    adm: AdmissionController,
    slo: SloTracker,
    q: CompletionQueue<SEv>,
    reqs: Vec<Request>,
    hp: Vec<HotplugEvent>,
    infer_inflight: BTreeMap<u64, InferBatch>,
    match_inflight: Option<MatchBatch>,
    limbo: Vec<InferBatch>,
    down: BTreeSet<u64>,
    detached_slot: BTreeMap<u8, u64>,
    next_batch: u64,
    dispatch_log: Vec<DispatchEntry>,
    requeued_total: u64,
    /// Per-request EDF queue entry time (admit or requeue), for the Queue
    /// span.  Only populated while tracing.
    queue_since: BTreeMap<u64, u64>,
    /// Clone of the orchestrator's recorder (off unless `cfg.trace`).
    obs: TraceRecorder,
    /// Black-box ring (off unless `cfg.flight`); teed the same spans and
    /// events as `obs`, plus the per-tick detector series.
    flight: FlightRecorder,
    /// Streaming detectors + burn-rate alerting (None unless the flight
    /// ring is armed or the governor is on — the engine feeds both).
    engine: Option<AnomalyEngine>,
    gov: Option<AdmissionGovernor>,
    anomaly_alerts: Vec<AnomalyAlert>,
    flight_dump: Option<PathBuf>,
    compactions: u64,
    /// True after a mid-run journal reopen failed: enrolls shed typed
    /// (`JournalStalled`) instead of acking without durability.
    journal_poisoned: bool,
    /// Previous-tick cumulative (bad, total) per class/tenant, diffed
    /// into the burn-rate windows each tick.
    prev_class: Vec<(u64, u64)>,
    prev_tenant: Vec<(u64, u64)>,
    /// Previous-tick cumulative counters behind the global series.
    prev_on_time: u64,
    prev_shed: u64,
    prev_terminal: u64,
    prev_defers: u64,
    prev_cache: (u64, u64),
    /// Completion latencies observed this tick (engine p99 series).
    tick_lat: Vec<u64>,
    t0: u64,
    capacity_rps: f64,
    offered_rps: f64,
    /// (uid, busy_us) snapshot after calibration, before serving.
    busy0: Vec<(u64, u64)>,
}

impl ServeSession {
    pub fn new(cfg: ServeConfig) -> anyhow::Result<Self> {
        cfg.profile.validate()?;
        anyhow::ensure!(cfg.requests >= 1, "need at least one request");
        anyhow::ensure!(cfg.requests <= u32::MAX as u64, "request count too large");
        anyhow::ensure!(cfg.gallery >= 1 && cfg.dim >= 8, "gallery/dim too small");
        anyhow::ensure!(cfg.overload > 0.0, "overload must be positive");
        anyhow::ensure!(cfg.batch >= 1 && cfg.window >= 1 && cfg.k >= 1);

        // The inference substrate: the paper's §4.2 face stack.
        let mut o = Orchestrator::new(BusProfile::usb3_gen1(), 6);
        if cfg.trace {
            // Installed before calibration and mount, so the engine's
            // warm-up spans and the boot-time unseal waves land in the
            // trace too.
            o.obs = TraceRecorder::enabled();
        }
        let mut stage_uids = Vec::new();
        for (i, cap) in [
            CapDescriptor::face_detect(),
            CapDescriptor::face_quality(),
            CapDescriptor::face_embed(),
        ]
        .into_iter()
        .enumerate()
        {
            stage_uids.push(o.plug(SlotId(i as u8), Cartridge::new(0, DeviceKind::Ncs2, cap))?);
        }

        // Serving from sealed media: mount (fail-closed) and decode the
        // gallery once, before a single request is admitted.  The mounted
        // index is the identify population; the in-memory index starts
        // empty as the enroll overlay + detach fallback.
        let mut mounts = None;
        let mut mounted_index: Option<Arc<GalleryIndex>> = None;
        let mut mounted_ivf: Option<Arc<IvfIndex>> = None;
        if let Some(path) = &cfg.image {
            let mut sup = MountSupervisor::with_key(SealKey::from_passphrase(&cfg.image_key));
            sup.set_recorder(o.obs.clone());
            sup.register_media(STORAGE_MEDIA_UID, path.clone());
            if sup.handle_attach(STORAGE_MEDIA_UID, 0).is_none() {
                let detail =
                    sup.events.last().map(|e| e.detail.clone()).unwrap_or_default();
                anyhow::bail!("cannot serve from {}: {detail}", path.display());
            }
            let idx = sup.gallery_index(STORAGE_MEDIA_UID).ok_or_else(|| {
                anyhow::anyhow!("image {} carries no gallery extent", path.display())
            })?;
            anyhow::ensure!(
                idx.dim() == cfg.dim,
                "image gallery dim {} != configured dim {} (pass --dim {})",
                idx.dim(),
                cfg.dim,
                idx.dim()
            );
            anyhow::ensure!(!idx.is_empty(), "image gallery is empty");
            // ANN tier, when the image carries one (decoded and
            // cross-checked at attach by the supervisor).
            mounted_ivf = sup.ivf_index(STORAGE_MEDIA_UID);
            mounted_index = Some(idx);
            mounts = Some(sup);
        }
        let gallery_rows = mounted_index.as_ref().map_or(cfg.gallery, |i| i.len());

        // Enroll the starting gallery through the SoA upsert path (skipped
        // when the mounted image is the population).
        let mut rng = Rng::new(cfg.seed ^ 0x9a11_e121_0c4e_5eed);
        let mut index = GalleryIndex::with_capacity(
            cfg.dim,
            if mounted_index.is_some() { 0 } else { cfg.gallery },
        );
        if mounted_index.is_none() {
            for i in 0..cfg.gallery {
                index.upsert(format!("id{i}"), &rng.unit_vec(cfg.dim));
            }
        }

        // Durable enrollment journal: open (write-ahead, fail-closed on
        // tamper), recover every acked frame from a previous run, and
        // fold the recovered set into the overlay before any traffic —
        // a power-cycled unit serves its acked enrollments immediately.
        let mut journal = None;
        let mut recovered: Vec<JournalRecord> = Vec::new();
        if let Some(jpath) = &cfg.journal {
            let img = mounts
                .as_ref()
                .and_then(|m| m.image(STORAGE_MEDIA_UID))
                .ok_or_else(|| anyhow::anyhow!("--journal requires a mounted --image"))?;
            let (j, recs) = EnrollJournal::open_for_image(
                jpath,
                &SealKey::from_passphrase(&cfg.image_key),
                img.image_uid(),
                img.manifest.compacted_from(),
            )?;
            fold_records(&recs, &mut index)?;
            journal = Some(j);
            recovered = recs;
        }

        // Calibrate pipeline capacity with a real engine run at the same
        // batch/window, so "overload 1.0" means what the event-driven
        // engine actually sustains through its credit windows.
        let cal_cfg = EngineConfig::batched(cfg.batch).with_window(cfg.window).with_warmup(4);
        let cal = o.run_pipelined_engine(&VideoSource::paper_stream(cfg.seed), 24, cal_cfg);
        let head_svc = o.carts[&stage_uids[0]].service_us.max(1);
        let infer_cap_rps = if cal.fps > 0.0 { cal.fps } else { 1e6 / head_svc as f64 };
        let identify_cap_rps = 1e6 / scan_pass_us(gallery_rows, cfg.dim, 1) as f64;

        let ident_share: f64 = cfg
            .profile
            .classes
            .iter()
            .filter(|c| c.kind == RequestKind::Identify)
            .map(|c| c.share)
            .sum();
        let infer_share = (1.0 - ident_share).max(0.0);
        let denom = ident_share / identify_cap_rps + infer_share / infer_cap_rps;
        let capacity_rps = 1.0 / denom.max(1e-9);
        let offered_rps = cfg.overload * capacity_rps;

        let t0 = o.clock.now();
        let reqs = traffic::generate(&cfg.profile, cfg.seed, cfg.requests, offered_rps, t0);
        let adm = AdmissionController::new(&cfg.profile, capacity_rps);
        let slo = SloTracker::new(
            cfg.requests,
            cfg.profile.classes.len(),
            cfg.profile.tenants.len(),
        );
        let mut flow = CreditFlow::new(cfg.window);
        flow.register(stage_uids[0]);

        let mut busy0: Vec<(u64, u64)> = stage_uids
            .iter()
            .map(|&uid| (uid, o.carts[&uid].timeline.busy_us()))
            .collect();
        busy0.sort_by_key(|&(uid, _)| uid);

        // The black box arms with the same seal passphrase as the media:
        // one operator secret decodes both the cartridge and its dumps.
        let flight = match &cfg.flight {
            Some(p) => {
                FlightRecorder::armed(cfg.seed, SealKey::from_passphrase(&cfg.image_key), p.clone())
            }
            None => FlightRecorder::off(),
        };
        let gov = cfg.governor.then(|| AdmissionGovernor::new(GovernorConfig::default()));
        let engine = (flight.is_enabled() || gov.is_some()).then(|| {
            AnomalyEngine::new(
                cfg.profile.classes.len(),
                cfg.profile.tenants.len(),
                SloBudget::default(),
            )
        });
        let prev_class = vec![(0, 0); cfg.profile.classes.len()];
        let prev_tenant = vec![(0, 0); cfg.profile.tenants.len()];

        let obs = o.obs.clone();
        Ok(ServeSession {
            cfg,
            o,
            stage_uids,
            index,
            mounts,
            mounted_index,
            mounted_ivf,
            journal,
            recovered,
            match_res: Resource::new(),
            flow,
            adm,
            slo,
            q: CompletionQueue::new(),
            reqs,
            hp: Vec::new(),
            infer_inflight: BTreeMap::new(),
            match_inflight: None,
            limbo: Vec::new(),
            down: BTreeSet::new(),
            detached_slot: BTreeMap::new(),
            next_batch: 0,
            dispatch_log: Vec::new(),
            requeued_total: 0,
            queue_since: BTreeMap::new(),
            obs,
            flight,
            engine,
            gov,
            anomaly_alerts: Vec::new(),
            flight_dump: None,
            compactions: 0,
            journal_poisoned: false,
            prev_class,
            prev_tenant,
            prev_on_time: 0,
            prev_shed: 0,
            prev_terminal: 0,
            prev_defers: 0,
            prev_cache: (0, 0),
            tick_lat: Vec::new(),
            t0,
            capacity_rps,
            offered_rps,
            busy0,
        })
    }

    /// Calibrated overload-1.0 offered rate, requests/s.
    pub fn capacity_rps(&self) -> f64 {
        self.capacity_rps
    }

    /// Journal records recovered (and folded into the overlay) at open.
    pub fn recovered_count(&self) -> usize {
        self.recovered.len()
    }

    /// Prove the replayed journal is actually serving: probe each
    /// recovered record with its exact stored template through the same
    /// two populations the identify path merges (mounted snapshot +
    /// overlay) and require rank-1 identity agreement.  Returns the
    /// number of records verified.
    pub fn verify_replay(&self) -> anyhow::Result<usize> {
        for r in &self.recovered {
            let best = self
                .identify_best(&r.template)
                .ok_or_else(|| anyhow::anyhow!("no population to resolve {:?} against", r.id))?;
            anyhow::ensure!(
                best == r.id,
                "recovered enrollment {:?} resolves to {best:?} after replay",
                r.id
            );
        }
        Ok(self.recovered.len())
    }

    /// Rank-1 identity across the mounted snapshot and the overlay.
    fn identify_best(&self, probe: &[f32]) -> Option<String> {
        let mut best: Option<(f32, String)> = None;
        for idx in [Some(&self.index), self.mounted_index.as_deref()].into_iter().flatten() {
            if idx.is_empty() {
                continue;
            }
            for (row, score) in idx.top_k(probe, 1) {
                if best.as_ref().map_or(true, |(s, _)| score > *s) {
                    best = Some((score, idx.id_of(row).to_string()));
                }
            }
        }
        best.map(|(_, id)| id)
    }

    #[cfg(test)]
    fn journal_mut(&mut self) -> Option<&mut EnrollJournal> {
        self.journal.as_mut()
    }

    /// The index Identify resolves against: the mounted image's gallery
    /// when media is in the bay, the in-memory index otherwise.
    fn active_index(&self) -> &GalleryIndex {
        self.mounted_index.as_deref().unwrap_or(&self.index)
    }

    /// The ANN tier Identify routes through, when one is usable: the
    /// media must be in the bay, the tier must cover the mounted snapshot,
    /// and a degenerate (tiny-gallery) tier is skipped — its searches
    /// would all fall back to exact anyway, so the exact batch kernel is
    /// strictly better.
    fn ann_tier(&self) -> Option<Arc<IvfIndex>> {
        let ivf = self.mounted_ivf.as_ref()?;
        let idx = self.mounted_index.as_ref()?;
        (!ivf.is_degenerate() && ivf.covers(idx)).then(|| ivf.clone())
    }

    /// Run to completion.  `events` are hot-plug actions with `at_us`
    /// *relative to serve start* (mission-trace convention); the OS
    /// notices them after the usual debounce/enumeration latency.
    pub fn run(mut self, events: Vec<HotplugEvent>) -> ServeOutcome {
        let t0 = self.t0;
        for (i, ev) in events.iter().enumerate() {
            self.q.push(t0 + ev.visible_at(), SEv::Hotplug(i as u32));
        }
        self.hp = events;
        for i in 0..self.reqs.len() {
            self.q.push(self.reqs[i].arrival_us, SEv::Arrival(i as u32));
        }
        self.q.push(t0 + TICK_US, SEv::HealthTick);

        while let Some(c) = self.q.pop() {
            let now = c.at_us;
            self.o.clock.advance_to(now);
            // Publish virtual "now" for clock-less writers (the vdisk
            // unseal walk stamps its wave records with this).
            self.obs.set_vnow(now);
            self.flight.set_vnow(now);
            match c.payload {
                SEv::Arrival(i) => self.on_arrival(i as usize, now),
                SEv::MatchDone(id) => self.on_match_done(id, now),
                SEv::InferDone(id) => self.on_infer_done(id, now),
                SEv::Hotplug(i) => self.on_hotplug(i as usize, now),
                SEv::HealthTick => self.on_tick(now),
            }
            self.pump(now);
        }
        self.finish()
    }

    // ------------------------------------------------------------- events

    /// True while any record sink is live (trace ring or flight ring):
    /// gates the span bookkeeping both tee into.
    fn observing(&self) -> bool {
        self.obs.is_enabled() || self.flight.is_enabled()
    }

    /// Record one span into every live sink.  Both recorders are no-ops
    /// when off, so the un-armed path stays zero-cost.
    fn span2(&self, t: TraceId, stage: Stage, t0: u64, t1: u64, a: u64, b: u64) {
        self.obs.span(t, stage, t0, t1, a, b);
        self.flight.span(t, stage, t0, t1, a, b);
    }

    /// Record one instant event into every live sink.
    fn event2(&self, t: TraceId, kind: EventKind, at: u64, a: u64, b: u64) {
        self.obs.event(t, kind, at, a, b);
        self.flight.event(t, kind, at, a, b);
    }

    fn on_arrival(&mut self, i: usize, now: u64) {
        let req = self.reqs[i];
        self.slo.offered(&req);
        self.o.reg.count("serve.offered", 1);
        self.o.reg.count(&format!("serve.tenant.{}.offered", req.tenant), 1);
        self.event2(
            TraceId::request(req.id),
            EventKind::Offered,
            now,
            req.class as u64,
            req.tenant as u64,
        );
        match self.adm.offer(req, now) {
            Admission::Admitted => {
                if self.observing() {
                    self.span2(
                        TraceId::request(req.id),
                        Stage::Admission,
                        now,
                        now,
                        req.class as u64,
                        req.tenant as u64,
                    );
                    self.queue_since.insert(req.id, now);
                }
            }
            Admission::Shed(reason) => self.record_shed(&req, reason, now),
        }
    }

    /// Terminal shed: SLO tally + registry counters + trace instant.
    fn record_shed(&mut self, req: &Request, reason: ShedReason, now: u64) {
        self.slo.shed(req, reason, now);
        self.o.reg.count(&format!("serve.shed.{}", reason.as_str()), 1);
        self.o.reg.count(&format!("serve.tenant.{}.shed", req.tenant), 1);
        if self.observing() {
            let code = match reason {
                ShedReason::RateLimited => 0,
                ShedReason::QueueFull => 1,
                ShedReason::Expired => 2,
                ShedReason::Evicted => 3,
                ShedReason::JournalStalled => 4,
            };
            self.event2(TraceId::request(req.id), EventKind::Shed, now, code, req.class as u64);
            self.queue_since.remove(&req.id);
        }
    }

    /// Terminal completion: SLO tally + registry counters + trace instant.
    fn record_completed(&mut self, req: &Request, now: u64) {
        self.slo.completed(req, now);
        self.o.reg.count("serve.completed", 1);
        self.o.reg.count(&format!("serve.tenant.{}.completed", req.tenant), 1);
        self.o.reg.observe("serve.latency_us", now.saturating_sub(req.arrival_us));
        if self.engine.is_some() {
            self.tick_lat.push(now.saturating_sub(req.arrival_us));
        }
        self.event2(
            TraceId::request(req.id),
            EventKind::Completed,
            now,
            (now <= req.deadline_us) as u64,
            req.class as u64,
        );
    }

    fn on_match_done(&mut self, id: u64, now: u64) {
        if self.match_inflight.as_ref().map(|b| b.id) != Some(id) {
            return;
        }
        let b = self.match_inflight.take().unwrap();
        for req in &b.reqs {
            self.record_completed(req, now);
        }
    }

    fn on_infer_done(&mut self, id: u64, now: u64) {
        // A batch evicted to limbo was removed from the in-flight map, so
        // its (now stale) completion event misses here and is ignored.
        let Some(b) = self.infer_inflight.remove(&id) else { return };
        for req in &b.reqs {
            if req.kind == RequestKind::Enroll {
                let vec = self.embedding_for(req.id);
                let eid = format!("enrolled-{}", req.id);
                // Write-ahead: the sealed frame must be durable before
                // the ack.  A journal that cannot take the write sheds
                // typed — never an ack the next mount cannot reproduce.
                if self.journal_poisoned {
                    self.o.reg.count("serve.journal_stalled", 1);
                    self.record_shed(req, ShedReason::JournalStalled, now);
                    self.flight_trigger(FlightTrigger::JournalStalled, req.id, now);
                    continue;
                }
                if let Some(j) = self.journal.as_mut() {
                    if j.append(&eid, &vec).is_err() {
                        self.o.reg.count("serve.journal_stalled", 1);
                        self.record_shed(req, ShedReason::JournalStalled, now);
                        self.flight_trigger(FlightTrigger::JournalStalled, req.id, now);
                        continue;
                    }
                    self.o.reg.count("serve.journal_appends", 1);
                }
                self.index.upsert(eid, &vec);
            }
            self.record_completed(req, now);
        }
        self.flow.release(self.stage_uids[0]);
        for &uid in &self.stage_uids {
            if !self.down.contains(&uid) {
                self.o.health.beat(uid, now);
            }
        }
    }

    fn on_hotplug(&mut self, i: usize, now: u64) {
        let ev = self.hp[i];
        let slot = ev.slot.0;
        // The storage bay: swap the sealed gallery media, not a pipeline
        // stage.  Detach unmounts and identify falls back to the
        // in-memory overlay; attach remounts (fail-closed) and swaps the
        // serving snapshot back in atomically.
        if slot == STORAGE_SLOT {
            if let Some(mounts) = self.mounts.as_mut() {
                match ev.kind {
                    HotplugKind::Detach => {
                        mounts.handle_detach(STORAGE_MEDIA_UID, now);
                        self.mounted_index = None;
                        self.mounted_ivf = None;
                        self.event2(
                            TraceId::STORAGE,
                            EventKind::MediaUnmount,
                            now,
                            STORAGE_MEDIA_UID,
                            0,
                        );
                    }
                    HotplugKind::Attach => {
                        if mounts.handle_attach(STORAGE_MEDIA_UID, now).is_some() {
                            self.mounted_index = mounts.gallery_index(STORAGE_MEDIA_UID);
                            self.mounted_ivf = mounts.ivf_index(STORAGE_MEDIA_UID);
                            self.event2(
                                TraceId::STORAGE,
                                EventKind::MediaMount,
                                now,
                                STORAGE_MEDIA_UID,
                                0,
                            );
                        }
                    }
                }
            }
            return;
        }
        match ev.kind {
            HotplugKind::Detach => {
                let Some(&uid) = self.stage_uids.get(slot as usize) else { return };
                if self.down.contains(&uid) {
                    return;
                }
                self.down.insert(uid);
                self.detached_slot.insert(slot, uid);
                // In-flight pipeline work is cancelled, never completed:
                // the batches move to limbo until eviction (health sweep)
                // or re-attach requeues them.
                let cancelled: Vec<u64> = self.infer_inflight.keys().copied().collect();
                for id in cancelled {
                    let b = self.infer_inflight.remove(&id).unwrap();
                    self.limbo.push(b);
                }
                // The surviving stages abandon the cancelled batches too:
                // clear their phantom reservations so requeued work does
                // not queue behind service that will never happen.
                for &stage in &self.stage_uids {
                    if stage != uid {
                        if let Some(c) = self.o.carts.get_mut(&stage) {
                            c.timeline.reset_to(now);
                        }
                    }
                }
            }
            HotplugKind::Attach => {
                let Some(uid) = self.detached_slot.remove(&slot) else { return };
                self.down.remove(&uid);
                // The module returns empty: reload its model before any
                // new work lands on its timeline.
                let load = self.o.carts[&uid].model_load_us();
                let cart = self.o.carts.get_mut(&uid).unwrap();
                cart.timeline.reset_to(now);
                cart.timeline.reserve(now, load);
                // Fresh heartbeat registration: the stale pre-detach beat
                // must not count against the recovered cartridge.
                self.o.health.register(uid, now);
                self.requeue_limbo(now);
            }
        }
    }

    fn on_tick(&mut self, now: u64) {
        // Keep-alive: present cartridges heartbeat whether or not traffic
        // reached them this tick; yanked ones cannot.
        for &uid in &self.stage_uids {
            if !self.down.contains(&uid) {
                self.o.health.beat(uid, now);
            }
        }
        // Queues must not hold unmeetable work while a server is down.
        let mut overdue = Vec::new();
        self.adm.expire_overdue(now, &mut overdue);
        for req in overdue {
            self.record_shed(&req, ShedReason::Expired, now);
        }
        self.o.reg.gauge("serve.queue_depth", self.adm.queued() as u64);
        self.o.reg.gauge(
            "serve.credit_in_flight",
            self.flow.in_flight(self.stage_uids[0]) as u64,
        );
        // HealthMonitor-driven eviction: a cartridge that stopped beating
        // is declared dead, its cancelled work is requeued (exactly once),
        // and it leaves the monitor until a re-attach registers it anew.
        let dead = self.o.health.sweep(now);
        for uid in dead {
            if self.stage_uids.contains(&uid) {
                self.requeue_limbo(now);
                self.o.health.deregister(uid);
                self.flight_trigger(FlightTrigger::Eviction, uid, now);
            }
        }
        self.anomaly_tick(now);
        self.maybe_compact(now);
        if self.slo.terminal_count < self.cfg.requests {
            self.q.push(now + TICK_US, SEv::HealthTick);
        }
    }

    /// Seal and dump the flight ring (first trigger wins; later calls are
    /// no-ops inside the recorder).
    fn flight_trigger(&mut self, trigger: FlightTrigger, detail: u64, now: u64) {
        if let Some(p) = self.flight.dump(trigger, detail) {
            self.obs.event(TraceId::STORAGE, EventKind::FlightDump, now, trigger as u64, detail);
            self.o.reg.count("serve.flight_dumps", 1);
            self.flight_dump = Some(p);
        }
    }

    /// One detector tick: diff the cumulative SLO tallies into per-scope
    /// `(bad, total)` deltas and the global series, feed the engine, tee
    /// alerts into both record sinks, and let the burn level drive the
    /// governor and the dump triggers.
    ///
    /// "Bad" deliberately excludes rate-limited sheds: those are the
    /// governor's own actuation, and counting them as burn would lock the
    /// loop into positive feedback (see `obs::detect`).
    fn anomaly_tick(&mut self, now: u64) {
        if self.engine.is_none() {
            return;
        }
        let scope_delta = |slo: &super::slo::ClassSlo, prev: &mut (u64, u64)| {
            let bad = (slo.completed - slo.on_time)
                + slo.shed_expired
                + slo.shed_evicted
                + slo.shed_queue_full
                + slo.shed_journal_stalled;
            let total = slo.completed + slo.shed_total() - slo.shed_rate_limited;
            let d = (bad - prev.0, total - prev.1);
            *prev = (bad, total);
            d
        };
        let mut class_bad = Vec::with_capacity(self.prev_class.len());
        let (mut on_time, mut shed, mut terminal) = (0u64, 0u64, 0u64);
        for i in 0..self.prev_class.len() {
            let c = self.slo.class(i);
            on_time += c.on_time;
            shed += c.shed_total();
            terminal += c.completed + c.shed_total();
            class_bad.push(scope_delta(c, &mut self.prev_class[i]));
        }
        let mut tenant_bad = Vec::with_capacity(self.prev_tenant.len());
        for i in 0..self.prev_tenant.len() {
            tenant_bad.push(scope_delta(self.slo.tenant(i), &mut self.prev_tenant[i]));
        }

        let mut series: Vec<(SeriesId, f64)> = Vec::with_capacity(5);
        series.push((SeriesId::Goodput, (on_time - self.prev_on_time) as f64));
        self.prev_on_time = on_time;
        if !self.tick_lat.is_empty() {
            self.tick_lat.sort_unstable();
            let idx = ((self.tick_lat.len() as f64 * 0.99).ceil() as usize)
                .clamp(1, self.tick_lat.len())
                - 1;
            series.push((SeriesId::P99, self.tick_lat[idx] as f64));
            self.tick_lat.clear();
        }
        let term_d = terminal - self.prev_terminal;
        let shed_d = shed - self.prev_shed;
        (self.prev_terminal, self.prev_shed) = (terminal, shed);
        if term_d > 0 {
            series.push((SeriesId::ShedRate, shed_d as f64 / term_d as f64));
        }
        if let Some(img) = self.mounts.as_ref().and_then(|m| m.image(STORAGE_MEDIA_UID)) {
            let cs = img.cache_stats();
            let (dh, dm) = (cs.hits - self.prev_cache.0, cs.misses - self.prev_cache.1);
            self.prev_cache = (cs.hits, cs.misses);
            if dh + dm > 0 {
                series.push((SeriesId::CacheHitRate, dh as f64 / (dh + dm) as f64));
            }
        }
        let defers = self.o.reg.counter_value("engine.bus.defers");
        series.push((SeriesId::BusDeferRate, (defers - self.prev_defers) as f64));
        self.prev_defers = defers;

        for &(s, v) in &series {
            self.flight.sample(s, now, v);
        }
        let sample = TickSample { t_us: now, class_bad, tenant_bad, series };
        let verdict = self.engine.as_mut().unwrap().tick(&sample);
        for alert in verdict.alerts {
            self.event2(
                TraceId::STORAGE,
                EventKind::Alert,
                now,
                alert.code(),
                alert.value.to_bits(),
            );
            let trigger = match alert.kind {
                AlertKind::Spike if alert.series == Some(SeriesId::ShedRate) => {
                    Some(FlightTrigger::ShedSpike)
                }
                AlertKind::BurnFast | AlertKind::BurnSlow => {
                    Some(FlightTrigger::DeadlineMissBurst)
                }
                _ => None,
            };
            if let Some(t) = trigger {
                self.flight_trigger(t, alert.code(), now);
            }
            self.anomaly_alerts.push(alert);
        }
        if let Some(g) = self.gov.as_mut() {
            if let Some(scale) = g.tick(verdict.burning) {
                self.adm.set_rate_scale(scale, now);
                self.o.reg.gauge("serve.governor_scale_pct", (scale * 100.0).round() as u64);
            }
        }
    }

    /// Background compaction: when the journal crosses the configured
    /// frame threshold, fold it into the image through the exact `champd
    /// vdisk compact` code path, then remount so the serving snapshot
    /// rides the new uid and reopen the reset journal against it.
    fn maybe_compact(&mut self, now: u64) {
        if self.cfg.compact_threshold == 0
            || self.mounted_index.is_none()
            || self.journal.as_ref().map_or(true, |j| j.frames() < self.cfg.compact_threshold)
        {
            return;
        }
        let (Some(image), Some(jpath)) = (self.cfg.image.clone(), self.cfg.journal.clone())
        else {
            return;
        };
        // Our append handle must not outlive the fold: compact truncates
        // and rebinds the journal file underneath it.
        let old_journal = self.journal.take();
        let opts = crate::cli::vdisk::CompactOptions {
            image,
            journal: jpath.clone(),
            passphrase: self.cfg.image_key.clone(),
            out: None,
        };
        let sum = match crate::cli::vdisk::compact(&opts) {
            Ok(s) => s,
            Err(e) => {
                // Fail safe: keep serving against the old image + journal
                // and stop retrying every tick.
                eprintln!("background compaction failed (serving continues): {e:#}");
                self.o.reg.count("serve.compaction_failed", 1);
                self.journal = old_journal;
                self.cfg.compact_threshold = 0;
                return;
            }
        };
        self.compactions += 1;
        self.o.reg.count("serve.compactions", 1);
        self.event2(
            TraceId::STORAGE,
            EventKind::MediaCompaction,
            now,
            sum.folded,
            sum.image.image_uid,
        );
        // Remount: the file at the image path is now the compacted image;
        // the in-memory snapshot (old uid) must not serve past this tick.
        if let Some(m) = self.mounts.as_mut() {
            m.handle_detach(STORAGE_MEDIA_UID, now);
            if m.handle_attach(STORAGE_MEDIA_UID, now).is_some() {
                self.mounted_index = m.gallery_index(STORAGE_MEDIA_UID);
                self.mounted_ivf = m.ivf_index(STORAGE_MEDIA_UID);
            } else {
                self.mounted_index = None;
                self.mounted_ivf = None;
            }
        }
        // Every overlay row was journal-backed and is now inside the
        // image: reset the overlay so passes stop double-scanning them.
        self.index = GalleryIndex::with_capacity(self.cfg.dim, 0);
        match EnrollJournal::open_for_image(
            &jpath,
            &SealKey::from_passphrase(&self.cfg.image_key),
            sum.image.image_uid,
            Some((sum.source_uid, sum.folded)),
        ) {
            Ok((j, _)) => self.journal = Some(j),
            Err(e) => {
                // No durable journal, no acks: enrolls shed typed from
                // here on instead of acking volatile state.
                eprintln!("journal reopen after compaction failed: {e:#}");
                self.journal_poisoned = true;
            }
        }
    }

    /// Requeue evicted in-flight work.  First eviction of a request puts
    /// it back in its class queue (original deadline, so EDF still holds);
    /// a second eviction sheds it — requeue happens exactly once.
    fn requeue_limbo(&mut self, now: u64) {
        let batches: Vec<InferBatch> = self.limbo.drain(..).collect();
        let head = self.stage_uids[0];
        for b in batches {
            for mut req in b.reqs {
                if req.requeued {
                    self.record_shed(&req, ShedReason::Evicted, now);
                } else {
                    req.requeued = true;
                    self.slo.requeued(&req);
                    self.requeued_total += 1;
                    self.o.reg.count("serve.requeued", 1);
                    self.event2(
                        TraceId::request(req.id),
                        EventKind::Requeued,
                        now,
                        req.class as u64,
                        req.tenant as u64,
                    );
                    if self.observing() {
                        self.queue_since.insert(req.id, now);
                    }
                    self.adm.requeue(req);
                }
            }
            self.flow.release(head);
        }
    }

    // ----------------------------------------------------------- dispatch

    fn pump(&mut self, now: u64) {
        self.pump_match(now);
        self.pump_infer(now);
    }

    /// Coalesce up to `batch` identify requests into one gallery pass
    /// against the active index (mounted sealed image, or the in-memory
    /// fallback while the media is out).
    fn pump_match(&mut self, now: u64) {
        if self.match_inflight.is_some() {
            return;
        }
        let rows = self.active_index().len();
        // The ANN tier makes a pass sub-linear: its virtual cost is the
        // rows a routed search actually touches (centroid scan + probed
        // lists) instead of the whole gallery.  Overlay rows (enrollments
        // journaled but not yet compacted into the image) ride the same
        // pass as an exact scan, so they are charged on top.
        let ivf = self.ann_tier();
        let overlay = if self.mounted_index.is_some() { self.index.len() } else { 0 };
        let base_rows = ivf.as_ref().map_or(rows, |t| t.expected_scan_rows(DEFAULT_NPROBE));
        // Dispatch guard at the max coalesced batch size (like the
        // pipeline's): the pass the request actually rides may carry up
        // to `batch` probes, and the guard must cover that completion.
        let est = scan_pass_us(base_rows + overlay, self.cfg.dim, self.cfg.batch as usize);
        let mut expired = Vec::new();
        let mut reqs: Vec<Request> = Vec::new();
        while reqs.len() < self.cfg.batch as usize {
            match self.adm.pop_dispatchable(now, false, est, &mut expired) {
                Some(r) => reqs.push(r),
                None => break,
            }
        }
        for req in expired {
            self.record_shed(&req, ShedReason::Expired, now);
        }
        if reqs.is_empty() {
            return;
        }
        // Adaptive nprobe: when the tightest deadline in the coalesced
        // batch leaves headroom, widen the probed lists (recall only goes
        // up — the default floor is the minimum ever probed), capped at
        // `nlist` where the tier's own fallback makes the pass exact.
        let mut nprobe = DEFAULT_NPROBE;
        if let Some(tier) = &ivf {
            let slack =
                reqs.iter().map(|r| r.deadline_us.saturating_sub(now)).min().unwrap_or(0);
            nprobe = boosted_nprobe(tier, self.cfg.dim, reqs.len(), overlay, slack);
            if nprobe > DEFAULT_NPROBE {
                self.o.reg.count("serve.ann_nprobe_boosted", reqs.len() as u64);
            }
        }
        let cost_rows = ivf.as_ref().map_or(rows, |t| t.expected_scan_rows(nprobe)) + overlay;
        // The actual engine call: the ANN tier routes each probe through
        // its lists (exact re-rank, exact fallback inside `search`);
        // otherwise one exact pass scores the whole batch.
        let probes: Vec<Vec<f32>> = reqs.iter().map(|r| self.probe_for(r.id)).collect();
        let refs: Vec<&[f32]> = probes.iter().map(|p| p.as_slice()).collect();
        let hits: Vec<Vec<(usize, f32)>> = match &ivf {
            Some(tier) => {
                let idx = self.active_index();
                refs.iter().map(|p| tier.search(idx, p, self.cfg.k, nprobe)).collect()
            }
            None => self.active_index().top_k_batch(&refs, self.cfg.k),
        };
        // Journal-only identities live in the overlay until compaction
        // folds them into the image: merge an exact overlay scan into
        // every mounted pass so they identify immediately.
        let hits: Vec<Vec<(usize, f32)>> = if overlay > 0 {
            hits.into_iter()
                .zip(&refs)
                .map(|(h, p)| merge_hits(h, self.index.top_k(p, self.cfg.k), self.cfg.k))
                .collect()
        } else {
            hits
        };
        debug_assert_eq!(hits.len(), reqs.len());
        // A mid-swap fallback index can legitimately be empty: zero-hit
        // identifies still complete (and account) normally.
        debug_assert!(rows + overlay == 0 || hits.iter().all(|h| !h.is_empty()));
        if ivf.is_some() {
            self.o.reg.count("serve.ann_served", reqs.len() as u64);
        }
        let (svc_start, done) =
            self.match_res.reserve(now, scan_pass_us(cost_rows, self.cfg.dim, reqs.len()));
        for r in &reqs {
            self.log_dispatch(r, now);
        }
        if self.observing() {
            // Span tiling: queue[admit,pop] + grant[pop,start] +
            // compute[start,done] sums exactly to completion − arrival.
            for r in &reqs {
                let t = TraceId::request(r.id);
                let since = self.queue_since.remove(&r.id).unwrap_or(r.arrival_us);
                self.span2(t, Stage::Queue, since, now, r.class as u64, r.tenant as u64);
                self.span2(t, Stage::Dispatch, now, now, reqs.len() as u64, 0);
                self.span2(t, Stage::BusGrant, now, svc_start, 0, 0);
                self.span2(t, Stage::Compute, svc_start, done, cost_rows as u64, reqs.len() as u64);
            }
        }
        let id = self.next_batch;
        self.next_batch += 1;
        self.match_inflight = Some(MatchBatch { id, reqs });
        self.q.push(done, SEv::MatchDone(id));
    }

    /// Batch inference requests onto the cartridge chain under the credit
    /// window.
    fn pump_infer(&mut self, now: u64) {
        if !self.down.is_empty() {
            return; // pipeline broken: requests wait (and expire typed)
        }
        let head = self.stage_uids[0];
        loop {
            if !self.flow.try_acquire(head) {
                return;
            }
            // Dispatch guard: estimated completion = wait for the head
            // timeline + the full chain for a max-size batch.  A request
            // that cannot meet its deadline under that estimate is shed
            // now instead of dispatched to miss.
            let head_wait = self.o.carts[&head].timeline.next_free().saturating_sub(now);
            let mut est = head_wait + self.chain_est_us(self.cfg.batch);
            // Under sustained burn the engaged governor pads the dispatch
            // guard: the raw estimate ignores stage-2/3 queue residency
            // behind the credit window, which is exactly where overload
            // misses come from.  The pad shrinks back to zero as the
            // scale recovers to 1.0.
            if let Some(g) = &self.gov {
                if g.engaged() {
                    est += ((1.0 - g.scale()) * est as f64) as u64;
                }
            }
            let mut expired = Vec::new();
            let mut reqs: Vec<Request> = Vec::new();
            while reqs.len() < self.cfg.batch as usize {
                match self.adm.pop_dispatchable(now, true, est, &mut expired) {
                    Some(r) => reqs.push(r),
                    None => break,
                }
            }
            for req in expired {
                self.record_shed(&req, ShedReason::Expired, now);
            }
            if reqs.is_empty() {
                self.flow.release(head);
                return;
            }
            let count = reqs.len() as u64;
            let mut t = now;
            let mut chain_start = None;
            for &uid in &self.stage_uids {
                let cart = self.o.carts.get_mut(&uid).unwrap();
                let handoff = stream_handoff_us(cart.kind);
                let dur = cart.service_us * count;
                let (svc_start, done) = cart.timeline.reserve(t + handoff, dur);
                if chain_start.is_none() {
                    chain_start = Some(svc_start);
                }
                t = done;
            }
            t += TAIL_US;
            for r in &reqs {
                self.log_dispatch(r, now);
            }
            if self.observing() {
                // Same tiling as the match path: the chain (all stages +
                // tail) is one Compute span from first-stage service start
                // to result return.
                let cs = chain_start.unwrap_or(now);
                for r in &reqs {
                    let tr = TraceId::request(r.id);
                    let since = self.queue_since.remove(&r.id).unwrap_or(r.arrival_us);
                    self.span2(tr, Stage::Queue, since, now, r.class as u64, r.tenant as u64);
                    self.span2(tr, Stage::Dispatch, now, now, count, 0);
                    self.span2(tr, Stage::BusGrant, now, cs, 0, 0);
                    self.span2(tr, Stage::Compute, cs, t, self.stage_uids.len() as u64, count);
                }
            }
            let id = self.next_batch;
            self.next_batch += 1;
            self.infer_inflight.insert(id, InferBatch { reqs });
            self.q.push(t, SEv::InferDone(id));
        }
    }

    /// Full-chain service estimate for a `count`-request batch.
    fn chain_est_us(&self, count: u32) -> u64 {
        let mut t = 0;
        for &uid in &self.stage_uids {
            let c = &self.o.carts[&uid];
            t += stream_handoff_us(c.kind) + c.service_us * count.max(1) as u64;
        }
        t + TAIL_US
    }

    fn log_dispatch(&mut self, req: &Request, now: u64) {
        self.dispatch_log.push(DispatchEntry {
            class: req.class,
            priority: req.priority,
            at_us: now,
            deadline_us: req.deadline_us,
            arrival_us: req.arrival_us,
        });
    }

    /// Deterministic probe for an identify request: a noisy copy of a row
    /// enrolled in the active index (the identification workload).  While
    /// no population is available (media out, empty overlay) the probe is
    /// a seeded unit vector — requests still serve, scores are just cold.
    fn probe_for(&self, id: u64) -> Vec<f32> {
        let mut rng = Rng::new(self.cfg.seed ^ id.wrapping_mul(0x85eb_ca6b_9e37_79b9));
        let idx = self.active_index();
        if idx.is_empty() {
            return rng.unit_vec(self.cfg.dim);
        }
        let row = (rng.next_u64() as usize) % idx.len();
        idx.row(row).iter().map(|v| v + 0.05 * rng.normal()).collect()
    }

    /// Deterministic embedding for an enroll request.
    fn embedding_for(&self, id: u64) -> Vec<f32> {
        let mut rng = Rng::new(self.cfg.seed ^ id.wrapping_mul(0xc2b2_ae3d_27d4_eb4f));
        rng.unit_vec(self.cfg.dim)
    }

    // ------------------------------------------------------------- report

    fn finish(self) -> ServeOutcome {
        let elapsed_us = self.slo.last_terminal_us.saturating_sub(self.t0).max(1);
        let classes = self.slo.summarize(&self.cfg.profile, elapsed_us);
        let offered: u64 = classes.iter().map(|c| c.offered).sum();
        let completed: u64 = classes.iter().map(|c| c.completed).sum();
        let shed: u64 = classes.iter().map(|c| c.shed).sum();
        let deadline_misses: u64 = classes.iter().map(|c| c.completed - c.on_time).sum();
        let post_admission_sheds: u64 = classes
            .iter()
            .map(|c| c.shed_expired + c.shed_evicted + c.shed_queue_full + c.shed_journal_stalled)
            .sum();

        // Publish the storage-side tallies into the registry before the
        // snapshot: cache effectiveness and the wave-admission savings.
        if let Some(img) = self.mounts.as_ref().and_then(|m| m.image(STORAGE_MEDIA_UID)) {
            let cs = img.cache_stats();
            self.o.reg.count("vdisk.cache.hits", cs.hits);
            self.o.reg.count("vdisk.cache.misses", cs.misses);
            self.o.reg.count("vdisk.cache.evictions", cs.evictions);
            self.o.reg.count("vdisk.cache.inserts", cs.inserts);
            self.o.reg.gauge("vdisk.cache.hit_rate_pct", (cs.hit_rate() * 100.0) as u64);
            self.o.reg.count(
                "vdisk.wave.saved_lock_acquisitions",
                img.cache_saved_lock_acquisitions(),
            );
        }

        // Tenant fairness rows: the shape comes from the tracker (exact
        // percentiles need the raw samples), the counters are read back
        // from the registry — the one place all layers publish into.
        let mut tenants = self.slo.summarize_tenants(&self.cfg.profile, elapsed_us);
        for (i, row) in tenants.iter_mut().enumerate() {
            row.offered = self.o.reg.counter_value(&format!("serve.tenant.{i}.offered"));
            row.completed = self.o.reg.counter_value(&format!("serve.tenant.{i}.completed"));
            row.shed = self.o.reg.counter_value(&format!("serve.tenant.{i}.shed"));
        }

        let trace = if self.obs.is_enabled() {
            Some(TraceSnapshot {
                records: self.obs.snapshot(),
                metrics: self.o.reg.snapshot(),
                dropped: self.obs.dropped(),
            })
        } else {
            None
        };

        // Power over the serving horizon: accelerator busy deltas (sorted
        // by uid for a deterministic f64 sum) plus the gallery-scan load
        // on the storage cartridge.
        let mut devices: Vec<(u64, DeviceProfile)> = self
            .busy0
            .iter()
            .map(|&(uid, b0)| {
                let busy = self.o.carts[&uid].timeline.busy_us().saturating_sub(b0);
                (busy.min(elapsed_us), self.o.carts[&uid].profile)
            })
            .collect();
        devices.push((self.match_res.busy_us().min(elapsed_us), DeviceProfile::storage()));
        let power = PowerModel::default().report(&devices, elapsed_us, completed);

        ServeOutcome {
            classes,
            tenants,
            offered,
            completed,
            shed,
            requeued: self.requeued_total,
            elapsed_us,
            power,
            alerts: self.o.health.alerts.clone(),
            dispatch_log: self.dispatch_log,
            capacity_rps: self.capacity_rps,
            offered_rps: self.offered_rps,
            ann_served: self.o.reg.counter_value("serve.ann_served"),
            ann_boosted: self.o.reg.counter_value("serve.ann_nprobe_boosted"),
            journal_appends: self.o.reg.counter_value("serve.journal_appends"),
            journal_recovered: self.recovered.len() as u64,
            accounting_ok: self.slo.accounting_holds(),
            media_events: self.mounts.map(|m| m.events).unwrap_or_default(),
            trace,
            anomaly_alerts: self.anomaly_alerts,
            flight_dump: self.flight_dump,
            governor_min_scale: self.gov.as_ref().map_or(1.0, |g| g.min_scale()),
            compactions: self.compactions,
            deadline_misses,
            post_admission_sheds,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bus::topology::SlotId;

    fn small_cfg(profile: MissionProfile, overload: f64, requests: u64) -> ServeConfig {
        let mut cfg = ServeConfig::new(profile);
        cfg.requests = requests;
        cfg.overload = overload;
        cfg.gallery = 512;
        cfg.dim = 32;
        cfg.seed = 11;
        cfg
    }

    #[test]
    fn smoke_run_completes_and_accounts() {
        let out = ServeSession::new(small_cfg(MissionProfile::checkpoint(), 1.0, 80))
            .unwrap()
            .run(vec![]);
        assert!(out.accounting_ok, "offered == completed + shed per class");
        assert_eq!(out.offered, 80);
        assert_eq!(out.offered, out.completed + out.shed);
        assert!(out.completed > 0);
        assert!(out.elapsed_us > 0);
        assert!(out.power.total_w > 0.0);
        assert!(out.power.frames_per_joule > 0.0);
    }

    #[test]
    fn underload_mostly_meets_deadlines() {
        let out = ServeSession::new(small_cfg(MissionProfile::checkpoint(), 0.5, 100))
            .unwrap()
            .run(vec![]);
        let on_time: u64 = out.classes.iter().map(|c| c.on_time).sum();
        assert!(
            on_time as f64 >= 0.85 * out.offered as f64,
            "0.5x load should serve nearly everything on time: {on_time}/{}",
            out.offered
        );
    }

    #[test]
    fn heavy_overload_sheds_but_never_drops_silently() {
        let out = ServeSession::new(small_cfg(MissionProfile::disaster_response(), 8.0, 150))
            .unwrap()
            .run(vec![]);
        assert!(out.accounting_ok);
        assert!(out.shed > 0, "8x offered load must shed");
        assert!(out.completed > 0, "overload must not starve the servers");
        // Typed shedding: every shed is attributed to a reason.
        let typed: u64 = out
            .classes
            .iter()
            .map(|c| {
                c.shed_rate_limited
                    + c.shed_queue_full
                    + c.shed_expired
                    + c.shed_evicted
                    + c.shed_journal_stalled
            })
            .sum();
        assert_eq!(typed, out.shed);
    }

    #[test]
    fn detach_then_immediate_reattach_requeues_exactly_once() {
        // 1.5x load keeps the pipeline backlogged, so the detach is
        // guaranteed to catch batches in flight.
        let cfg = small_cfg(MissionProfile::disaster_response(), 1.5, 200);
        let session = ServeSession::new(cfg).unwrap();
        let events = vec![
            HotplugEvent { at_us: 1_000_000, slot: SlotId(0), kind: HotplugKind::Detach, uid: 0 },
            HotplugEvent { at_us: 1_000_000, slot: SlotId(0), kind: HotplugKind::Attach, uid: 0 },
        ];
        let out = session.run(events);
        assert!(out.accounting_ok);
        assert!(out.requeued > 0, "in-flight work at detach must requeue");
        assert!(out.requeued <= 4, "requeue bounded by window x batch");
        // Quick re-attach: no eviction alert needed.
        assert!(out.alerts.is_empty(), "unexpected alerts: {:?}", out.alerts);
    }

    #[test]
    fn delayed_reattach_evicts_via_health_sweep_with_one_alert() {
        let cfg = small_cfg(MissionProfile::disaster_response(), 1.5, 250);
        let session = ServeSession::new(cfg).unwrap();
        let events = vec![
            HotplugEvent { at_us: 1_000_000, slot: SlotId(0), kind: HotplugKind::Detach, uid: 0 },
            HotplugEvent { at_us: 3_000_000, slot: SlotId(0), kind: HotplugKind::Attach, uid: 0 },
        ];
        let out = session.run(events);
        assert!(out.accounting_ok);
        assert_eq!(
            out.alerts.len(),
            1,
            "exactly the eviction alert, none after re-attach: {:?}",
            out.alerts
        );
        assert!(out.alerts[0].text.contains("stopped responding"));
        assert!(out.completed > 0, "serving resumes after re-attach");
    }

    #[test]
    fn same_seed_same_outcome() {
        let run = |seed| {
            let mut cfg = small_cfg(MissionProfile::watchlist(), 2.0, 120);
            cfg.seed = seed;
            ServeSession::new(cfg).unwrap().run(vec![])
        };
        let (a, b) = (run(5), run(5));
        assert_eq!(a.offered, b.offered);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.elapsed_us, b.elapsed_us);
        for (x, y) in a.classes.iter().zip(&b.classes) {
            assert_eq!((x.p50_us, x.p99_us, x.on_time), (y.p50_us, y.p99_us, y.on_time));
            assert!(x.goodput_rps.to_bits() == y.goodput_rps.to_bits());
        }
        assert!(a.power.total_w.to_bits() == run(5).power.total_w.to_bits());
        let c = run(6);
        assert!(a.completed != c.completed || a.elapsed_us != c.elapsed_us);
    }

    #[test]
    fn scan_cost_amortizes_across_the_batch() {
        let one = scan_pass_us(10_000, 128, 1);
        let four = scan_pass_us(10_000, 128, 4);
        assert!(four < 4 * one, "batch pass must beat 4 single passes");
        assert!(four > one, "more probes still cost more");
    }

    // ---- serving from a sealed image ------------------------------------

    fn packed_image(tag: &str, n: usize, dim: usize, pass: &str) -> std::path::PathBuf {
        use crate::biometric::gallery::Gallery;
        use crate::vdisk::ImageBuilder;
        let dir =
            std::env::temp_dir().join(format!("champ-servimg-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(41);
        let mut idx = GalleryIndex::with_capacity(dim, n);
        for i in 0..n {
            idx.upsert(format!("sub{i}"), &rng.unit_vec(dim));
        }
        let path = dir.join("media.vdisk");
        ImageBuilder::new("serve-media")
            .gallery(&Gallery::from_index(idx))
            .block_size(512)
            .write(&path, &SealKey::from_passphrase(pass))
            .unwrap();
        path
    }

    fn image_cfg(path: std::path::PathBuf, requests: u64) -> ServeConfig {
        let mut cfg = small_cfg(MissionProfile::checkpoint(), 1.5, requests);
        cfg.dim = 32;
        cfg.image = Some(path);
        cfg.image_key = "serve-media-key".into();
        cfg
    }

    #[test]
    fn identify_serves_from_the_mounted_image() {
        let path = packed_image("run", 256, 32, "serve-media-key");
        let out = ServeSession::new(image_cfg(path, 100)).unwrap().run(vec![]);
        assert!(out.accounting_ok);
        assert_eq!(out.offered, out.completed + out.shed);
        assert!(out.completed > 0, "identify must serve from the sealed image");
        let kinds: Vec<_> = out.media_events.iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec![crate::vdisk::MountEventKind::Mounted]);
    }

    #[test]
    fn storage_detach_falls_back_and_reattach_swaps_the_index_back() {
        use crate::vdisk::MountEventKind::{Mounted, Unmounted};
        let path = packed_image("swap", 256, 32, "serve-media-key");
        let events = vec![
            HotplugEvent {
                at_us: 500_000,
                slot: SlotId(STORAGE_SLOT),
                kind: HotplugKind::Detach,
                uid: 0,
            },
            HotplugEvent {
                at_us: 2_000_000,
                slot: SlotId(STORAGE_SLOT),
                kind: HotplugKind::Attach,
                uid: 0,
            },
        ];
        let out = ServeSession::new(image_cfg(path, 200)).unwrap().run(events);
        assert!(out.accounting_ok, "fallback must not break exactly-once accounting");
        assert_eq!(out.offered, out.completed + out.shed);
        assert!(out.completed > 0);
        let kinds: Vec<_> = out.media_events.iter().map(|e| e.kind).collect();
        assert_eq!(kinds, vec![Mounted, Unmounted, Mounted], "{:?}", out.media_events);
    }

    #[test]
    fn identify_routes_through_the_mounted_ann_tier() {
        use crate::biometric::gallery::Gallery;
        use crate::biometric::ivf::{clustered_index, IvfIndex, IvfParams};
        use crate::vdisk::ImageBuilder;

        // A clustered gallery big enough to train a real (non-degenerate)
        // tier, packed with its IVF extent.
        let dir =
            std::env::temp_dir().join(format!("champ-servann-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(83);
        let idx = clustered_index(&mut rng, 800, 32, 28, 0.5);
        let ivf = IvfIndex::train(&idx, &IvfParams::default());
        assert!(!ivf.is_degenerate());
        let path = dir.join("ann-media.vdisk");
        ImageBuilder::new("ann-serve")
            .gallery(&Gallery::from_index(idx))
            .ivf(ivf.encode())
            .block_size(512)
            .write(&path, &SealKey::from_passphrase("serve-media-key"))
            .unwrap();

        let out = ServeSession::new(image_cfg(path.clone(), 100)).unwrap().run(vec![]);
        assert!(out.accounting_ok);
        assert!(out.completed > 0);
        assert!(out.ann_served > 0, "identify must resolve through the ANN tier");

        // Yank the media: identify falls back to the exact overlay and the
        // ANN counter stops advancing; re-attach resumes routed serving.
        let events = vec![
            HotplugEvent {
                at_us: 500_000,
                slot: SlotId(STORAGE_SLOT),
                kind: HotplugKind::Detach,
                uid: 0,
            },
        ];
        let swapped = ServeSession::new(image_cfg(path, 200)).unwrap().run(events);
        assert!(swapped.accounting_ok, "ANN fallback must not break accounting");
        assert!(swapped.completed > 0);
        assert!(
            swapped.ann_served < swapped.completed,
            "post-detach identifies must not count as ANN-served"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- durable enrollment journal -------------------------------------

    fn enrolls_of(out: &ServeOutcome) -> u64 {
        out.classes
            .iter()
            .filter(|c| c.kind == RequestKind::Enroll)
            .map(|c| c.completed)
            .sum()
    }

    #[test]
    fn enrollments_survive_a_power_cycle_through_the_journal() {
        let path = packed_image("jrnl", 256, 32, "serve-media-key");
        let jpath = path.with_file_name("enroll.cjl");
        let mut cfg = image_cfg(path, 150);
        cfg.journal = Some(jpath.clone());

        let out = ServeSession::new(cfg.clone()).unwrap().run(vec![]);
        assert!(out.accounting_ok);
        let enrolled = enrolls_of(&out);
        assert!(enrolled > 0, "profile must complete some enrollments");
        assert_eq!(out.journal_appends, enrolled, "every ack needs a durable frame");
        assert_eq!(out.journal_recovered, 0, "first boot recovers nothing");

        // "Power cycle": a fresh session over the same media + journal
        // recovers exactly the acked set, and every recovered identity
        // resolves rank-1 through the merged identify path.
        let s2 = ServeSession::new(cfg.clone()).unwrap();
        assert_eq!(s2.recovered_count() as u64, enrolled);
        assert_eq!(s2.verify_replay().unwrap() as u64, enrolled);
        let out2 = s2.run(vec![]);
        assert!(out2.accounting_ok);
        assert_eq!(out2.journal_recovered, enrolled);

        // Third boot: the journal holds both runs' acked enrollments.
        let s3 = ServeSession::new(cfg).unwrap();
        assert_eq!(s3.recovered_count() as u64, enrolled + enrolls_of(&out2));
        assert_eq!(s3.verify_replay().unwrap(), s3.recovered_count());
    }

    #[test]
    fn journal_stall_sheds_typed_instead_of_acking_volatile() {
        let path = packed_image("stall", 256, 32, "serve-media-key");
        let jpath = path.with_file_name("stall.cjl");
        let mut cfg = image_cfg(path, 150);
        cfg.journal = Some(jpath);

        let mut s = ServeSession::new(cfg.clone()).unwrap();
        s.journal_mut().unwrap().fail_next_appends(u32::MAX);
        let out = s.run(vec![]);
        assert!(out.accounting_ok, "stalls must stay exactly-once accounted");
        assert_eq!(out.journal_appends, 0);
        assert_eq!(enrolls_of(&out), 0, "no ack without a durable frame");
        let stalled: u64 = out.classes.iter().map(|c| c.shed_journal_stalled).sum();
        assert!(stalled > 0, "enrolls must shed typed while the journal is down");

        // The next boot sees an empty journal: nothing was ever acked,
        // so nothing may be recovered.
        let s2 = ServeSession::new(cfg).unwrap();
        assert_eq!(s2.recovered_count(), 0);
    }

    #[test]
    fn journal_without_image_is_rejected() {
        let mut cfg = small_cfg(MissionProfile::checkpoint(), 1.0, 50);
        cfg.journal = Some(std::env::temp_dir().join("champ-no-image.cjl"));
        let e = ServeSession::new(cfg).unwrap_err().to_string();
        assert!(e.contains("requires a mounted --image"), "{e}");
    }

    // ---- adaptive nprobe ------------------------------------------------

    fn ann_image(tag: &str) -> std::path::PathBuf {
        use crate::biometric::gallery::Gallery;
        use crate::biometric::ivf::{clustered_index, IvfIndex, IvfParams};
        use crate::vdisk::ImageBuilder;
        let dir =
            std::env::temp_dir().join(format!("champ-servnp-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let mut rng = Rng::new(83);
        let idx = clustered_index(&mut rng, 800, 32, 28, 0.5);
        let ivf = IvfIndex::train(&idx, &IvfParams::default());
        assert!(!ivf.is_degenerate());
        let path = dir.join("np-media.vdisk");
        ImageBuilder::new("np-serve")
            .gallery(&Gallery::from_index(idx))
            .ivf(ivf.encode())
            .block_size(512)
            .write(&path, &SealKey::from_passphrase("serve-media-key"))
            .unwrap();
        path
    }

    #[test]
    fn boosted_nprobe_never_drops_below_the_floor_and_caps_at_nlist() {
        use crate::biometric::ivf::{clustered_index, IvfIndex, IvfParams};
        let mut rng = Rng::new(91);
        let idx = clustered_index(&mut rng, 800, 32, 28, 0.5);
        let tier = IvfIndex::train(&idx, &IvfParams::default());
        assert!(tier.nlist() > DEFAULT_NPROBE);
        // No slack: the committed default, never narrower.
        assert_eq!(boosted_nprobe(&tier, 32, 2, 0, 0), DEFAULT_NPROBE);
        // Unbounded slack: widened, but never past nlist.
        let wide = boosted_nprobe(&tier, 32, 2, 0, u64::MAX);
        assert!(wide > DEFAULT_NPROBE, "headroom must widen the probe");
        assert!(wide <= tier.nlist());
        // Monotone in slack, floored at the default everywhere.
        let mut prev = 0usize;
        for slack in [0u64, 1_000, 10_000, 100_000, 10_000_000] {
            let np = boosted_nprobe(&tier, 32, 2, 0, slack);
            assert!(np >= DEFAULT_NPROBE && np >= prev, "slack {slack}: {np}");
            prev = np;
        }
    }

    #[test]
    fn deadline_headroom_widens_the_ann_probe() {
        let path = ann_image("boost");
        let mut cfg = image_cfg(path, 100);
        cfg.overload = 0.25;
        let out = ServeSession::new(cfg).unwrap().run(vec![]);
        assert!(out.accounting_ok);
        assert!(out.ann_served > 0);
        assert!(
            out.ann_boosted > 0,
            "underloaded identify with 250ms+ deadlines must widen nprobe"
        );
    }

    // ---- closed-loop admission governor ---------------------------------

    #[test]
    fn governor_engages_and_reduces_misses_under_overload() {
        for overload in [4.0, 8.0] {
            let base = small_cfg(MissionProfile::disaster_response(), overload, 250);
            let mut governed = base.clone();
            governed.governor = true;
            let un = ServeSession::new(base).unwrap().run(vec![]);
            let gov = ServeSession::new(governed).unwrap().run(vec![]);
            assert!(un.accounting_ok && gov.accounting_ok);
            assert!(gov.completed > 0, "{overload}x: governed serving must not starve");
            assert_eq!(un.governor_min_scale.to_bits(), 1.0f64.to_bits());
            assert!(
                gov.governor_min_scale < 1.0,
                "{overload}x overload must engage the governor"
            );
            // The control objective: late work (deadline misses + sheds
            // discovered after admission) strictly shrinks; the governor
            // turns it into cheap front-door rate limiting instead.
            assert!(
                gov.deadline_misses < un.deadline_misses || un.deadline_misses == 0,
                "{overload}x: misses {} must drop below ungoverned {}",
                gov.deadline_misses,
                un.deadline_misses
            );
            let (u, g) = (
                un.deadline_misses + un.post_admission_sheds,
                gov.deadline_misses + gov.post_admission_sheds,
            );
            assert!(g < u, "{overload}x: governed late work {g} must beat ungoverned {u}");
        }
    }

    // ---- black-box flight recorder --------------------------------------

    #[test]
    fn armed_flight_changes_no_outcome_and_dumps_deterministically() {
        let dir = std::env::temp_dir().join(format!("champ-servflt-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();

        // Armed but never triggered (0.5x underload): bit-identical
        // numbers to off, and no sidecar file ever appears.
        let calm = small_cfg(MissionProfile::checkpoint(), 0.5, 100);
        let mut armed = calm.clone();
        armed.flight = Some(dir.join("calm.bbx"));
        let off = ServeSession::new(calm).unwrap().run(vec![]);
        let on = ServeSession::new(armed).unwrap().run(vec![]);
        assert_eq!(
            (off.offered, off.completed, off.shed, off.elapsed_us),
            (on.offered, on.completed, on.shed, on.elapsed_us)
        );
        for (x, y) in off.classes.iter().zip(&on.classes) {
            assert_eq!((x.p50_us, x.p99_us, x.on_time), (y.p50_us, y.p99_us, y.on_time));
            assert_eq!(x.goodput_rps.to_bits(), y.goodput_rps.to_bits());
        }
        assert!(on.flight_dump.is_none());
        assert!(!dir.join("calm.bbx").exists(), "untriggered ring must not dump");

        // 8x overload: the burn detectors trip, the ring seals to the
        // sidecar, and the dump is byte-identical for the same seed.
        let run_dump = |tag: &str| -> Vec<u8> {
            let mut cfg = small_cfg(MissionProfile::disaster_response(), 8.0, 250);
            cfg.flight = Some(dir.join(format!("{tag}.bbx")));
            let out = ServeSession::new(cfg).unwrap().run(vec![]);
            assert!(!out.anomaly_alerts.is_empty(), "8x must raise alerts");
            let p = out.flight_dump.expect("8x must trigger a dump");
            std::fs::read(p).unwrap()
        };
        let (a, b) = (run_dump("hot-a"), run_dump("hot-b"));
        assert_eq!(a, b, "same seed, same sealed dump bytes");
        let dump = crate::obs::flight::decode_dump_bytes(
            &a,
            &SealKey::from_passphrase("champ-dev-key"),
        )
        .unwrap();
        assert_eq!(dump.seed, 11);
        assert!(!dump.records.is_empty());
        assert!(!dump.truncated);
        std::fs::remove_dir_all(&dir).ok();
    }

    // ---- background journal compaction ----------------------------------

    #[test]
    fn background_compaction_folds_mid_run_and_survives_the_power_cycle() {
        let path = packed_image("bgc", 256, 32, "serve-media-key");
        let jpath = path.with_file_name("bgc.cjl");
        let mut cfg = image_cfg(path.clone(), 150);
        cfg.journal = Some(jpath.clone());
        cfg.compact_threshold = 2;

        let out = ServeSession::new(cfg.clone()).unwrap().run(vec![]);
        assert!(out.accounting_ok, "compaction must not break exactly-once accounting");
        let enrolled = enrolls_of(&out);
        assert!(enrolled > 0);
        assert!(out.compactions >= 1, "threshold 2 must fold mid-run: {:?}", out.compactions);

        // The folded enrollments live inside the sealed image now: it
        // mounts clean with more rows than packed, carrying provenance.
        let img = crate::vdisk::MountedImage::mount(
            &path,
            &SealKey::from_passphrase("serve-media-key"),
        )
        .unwrap();
        let (idx, _) = img.load_gallery_index().unwrap();
        assert!(idx.len() > 256, "folded rows must be in the image: {}", idx.len());
        assert!(img.manifest.compacted_from().is_some());
        drop(img);

        // Power cycle: the next boot recovers only the post-compaction
        // tail from the journal, and every acked enrollment — folded or
        // tailed — still resolves rank-1.
        let s2 = ServeSession::new(cfg).unwrap();
        assert!(
            (s2.recovered_count() as u64) < enrolled,
            "folded frames must have left the journal: {} of {enrolled}",
            s2.recovered_count()
        );
        assert_eq!(s2.verify_replay().unwrap(), s2.recovered_count());
        let out2 = s2.run(vec![]);
        assert!(out2.accounting_ok);
        std::fs::remove_dir_all(path.parent().unwrap()).ok();
    }

    #[test]
    fn image_session_fails_closed_on_wrong_key_or_dim() {
        let path = packed_image("bad", 64, 32, "serve-media-key");
        let mut cfg = image_cfg(path.clone(), 50);
        cfg.image_key = "wrong".into();
        let e = ServeSession::new(cfg).unwrap_err().to_string();
        assert!(e.contains("cannot serve from"), "{e}");
        let mut cfg = image_cfg(path, 50);
        cfg.dim = 16;
        let e = ServeSession::new(cfg).unwrap_err().to_string();
        assert!(e.contains("dim"), "{e}");
    }
}
