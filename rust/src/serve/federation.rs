//! Scale-out federation: scatter-gather serving across a rack of units.
//!
//! One CHAMP unit saturates its USB3 bus at five accelerators, so serving
//! millions of identities scales *out*: a rack of units, each mounting a
//! gallery shard. This module is the router over that rack.
//!
//! * **Placement** — rendezvous hashing ([`super::shard`]) puts every
//!   identity on the `replication` highest-weight units. A probe for a key
//!   is *routed* to the best-ranked live unit holding a copy, so a unit
//!   detach (the cartridge hot-swap machinery generalized to whole units,
//!   [`crate::bus::hotplug::UnitEvent`]) degrades to the replica without
//!   moving a byte.
//! * **Scatter-gather** — `Identify` fans out as per-unit `top_k` probes
//!   over each unit's *currently routed* key set (`std::thread::scope`, one
//!   virtual-time session per unit), and the per-unit answers fold through
//!   [`crate::biometric::search::merge_topk`]: the same `f32::total_cmp`
//!   order and enrollment-order tie-break as one scan, so the merged result
//!   is bit-identical to a single-unit scan over the union. The routed sets
//!   partition the corpus exactly once, which is both why the merge needs
//!   no dedup and why per-unit scan cost shrinks as ~corpus/N — the whole
//!   point of the tier.
//! * **Durability** — with journals attached, an acked `Enroll` is
//!   write-ahead appended to the journal of *every* replica before the ack,
//!   so a single unit loss loses no acked enrollment.
//! * **Rebalance** — racking an *additional* unit queues per-identity copy
//!   transfers; they drain incrementally (bounded batch per tick) and are
//!   exactly-once accounted through the same [`SloTracker`] state machine
//!   that guards request outcomes. Routing flips per key only once its copy
//!   is resident, so mid-rebalance probes never hit a hole.
//!
//! [`run`] drives the whole tier under open-loop traffic in virtual time:
//! same seed, same outcome, on any machine — which is what lets the
//! goodput-vs-units scaling contract be gated in CI.

use std::collections::VecDeque;
use std::path::{Path, PathBuf};
use std::thread;

use crate::biometric::index::GalleryIndex;
use crate::biometric::search::merge_topk;
use crate::bus::hotplug::{HotplugKind, UnitEvent, UnitScript};
use crate::coordinator::completion::CompletionQueue;
use crate::crypto::seal::SealKey;
use crate::obs::recorder::{Stage, TraceId, TraceRecord, TraceRecorder};
use crate::util::rng::Rng;
use crate::vdisk::{EnrollJournal, JournalRecord};

use super::admission::{Admission, AdmissionController, ShedReason};
use super::session::scan_pass_us;
use super::shard::{placement_key, ShardMap};
use super::slo::{ClassOutcome, SloTracker, TenantOutcome};
use super::traffic::{self, MissionProfile, Request, RequestKind};

/// Router-side fan-out cost: request framing plus one sub-query post per
/// probed unit, virtual us.
const SCATTER_BASE_US: u64 = 150;
const SCATTER_PER_UNIT_US: u64 = 25;

/// Gather-side merge cost: heap setup plus a per-candidate term over the
/// k×units merged entries, virtual us.
const MERGE_BASE_US: u64 = 20;

/// Virtual service cost of a federated enroll (embed + placement), before
/// the per-replica journal append cost.
const ENROLL_BASE_US: u64 = 20_000;
const JOURNAL_APPEND_US: u64 = 800;

/// Virtual service cost of a non-sharded inference request (ArtifactRun):
/// the pipeline chain does not scale with unit count, so it is a constant
/// server here.
const INFER_US: u64 = 30_000;

/// Health/expiry/rebalance tick period, matching the session heartbeat.
const TICK_US: u64 = 100_000;

/// Copy transfers drained per rebalance tick.
const REBALANCE_BATCH: usize = 64;

/// Transfer-id marker for copies queued by enrolls that arrived while an
/// expansion was still draining (accounted outside the attach-time batch).
const DEFERRED_TID: u64 = u64::MAX;

/// One resident copy of an identity: which unit, and the local SoA row.
#[derive(Debug, Clone, Copy)]
struct Replica {
    unit: u32,
    row: u32,
}

/// Placement record for one enrolled identity, in global enrollment order
/// (the vec index *is* the global sequence — the merge tie-break).
#[derive(Debug, Clone)]
struct Enrolled {
    id: String,
    key: u64,
    replicas: Vec<Replica>,
}

/// One simulated unit: its shard index plus the local→global row map. The
/// unit is a self-contained virtual-time session — scatter sub-queries run
/// against it on their own thread, and its journal (when attached) is the
/// unit's own durable stream.
struct UnitSession {
    uid: u64,
    index: GalleryIndex,
    /// Local row → global enrollment sequence. Rows land in global
    /// enrollment order, so this is strictly increasing — which is what
    /// makes the per-unit local tie-break agree with the global one.
    global_seq: Vec<u32>,
    journal: Option<EnrollJournal>,
}

/// The in-flight expansion, exactly-once accounted: every attach-time copy
/// is `offered` to the tracker when the unit racks and `completed` when the
/// copy lands; enroll-time deferrals are tallied alongside. "Holds" means
/// no transfer was ever lost or double-applied.
struct RebalanceOp {
    slo: SloTracker,
    pending: VecDeque<(u32, u32, u64)>, // (global seq, target unit, transfer id)
    total: u64,
    target: u32,
    deferred_offered: u64,
    deferred_done: u64,
}

/// Virtual-time cost breakdown of one scatter-gather pass.
#[derive(Debug, Clone)]
pub struct ScatterStats {
    pub units_probed: usize,
    pub scatter_us: u64,
    /// Slowest per-unit scan — the gather waits for it.
    pub probe_wait_us: u64,
    pub merge_us: u64,
    /// (unit uid, scan us) per probed unit.
    pub per_unit_us: Vec<(u64, u64)>,
}

impl ScatterStats {
    pub fn total_us(&self) -> u64 {
        self.scatter_us + self.probe_wait_us + self.merge_us
    }
}

/// The federation router: shard placement + per-unit sessions + the
/// deterministic gather.
pub struct FederationRouter {
    dim: usize,
    map: ShardMap,
    units: Vec<UnitSession>,
    enrolled: Vec<Enrolled>,
    /// Per unit: global sequences currently *routed* here (sorted
    /// ascending — enrollment order). These sets partition the routable
    /// corpus: every live-replicated key appears in exactly one.
    assigned: Vec<Vec<u32>>,
    /// Keys whose every replica is down (only possible once ≥ RF units are
    /// out). They shed nothing here — they simply stop matching until a
    /// replica returns.
    unroutable: usize,
    rebalance: Option<RebalanceOp>,
}

impl FederationRouter {
    pub fn new(dim: usize, unit_uids: &[u64], replication: usize) -> Self {
        let map = ShardMap::new(unit_uids, replication);
        let units = unit_uids
            .iter()
            .map(|&uid| UnitSession {
                uid,
                index: GalleryIndex::new(dim),
                global_seq: Vec::new(),
                journal: None,
            })
            .collect();
        FederationRouter {
            dim,
            map,
            units,
            enrolled: Vec::new(),
            assigned: vec![Vec::new(); unit_uids.len()],
            unroutable: 0,
            rebalance: None,
        }
    }

    /// Attach one journal per unit under `dir`, each sealed with `key` and
    /// bound to its unit uid. Existing journals replay first: recovered
    /// records re-enroll (idempotently — replicas of the same id carry the
    /// same bytes), so a power-cycled rack comes back with every acked
    /// enrollment even after losing up to RF−1 of its journals.
    pub fn with_journals(mut self, dir: &Path, key: &str) -> anyhow::Result<Self> {
        std::fs::create_dir_all(dir)?;
        let seal = SealKey::from_passphrase(key);
        let mut recovered: Vec<(u64, JournalRecord)> = Vec::new();
        for u in 0..self.units.len() {
            let uid = self.units[u].uid;
            let (j, recs) =
                EnrollJournal::open_for_image(&Self::journal_path(dir, uid), &seal, uid, None)?;
            self.units[u].journal = Some(j);
            recovered.extend(recs.into_iter().map(|r| (uid, r)));
        }
        // Deterministic replay order across units: by per-unit ack seq,
        // then unit uid. Within one unit this is the original enrollment
        // order; across units it is a fixed interleave. The replay path
        // does not re-append (the records came *from* the journals).
        recovered.sort_by(|a, b| a.1.seq.cmp(&b.1.seq).then(a.0.cmp(&b.0)));
        for (_, rec) in recovered {
            self.enroll_inner(&rec.id, &rec.template, false)?;
        }
        Ok(self)
    }

    fn journal_path(dir: &Path, uid: u64) -> PathBuf {
        dir.join(format!("unit-{uid:x}.journal"))
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn unit_count(&self) -> usize {
        self.units.len()
    }

    pub fn live_count(&self) -> usize {
        self.map.live_count()
    }

    pub fn replication(&self) -> usize {
        self.map.replication()
    }

    pub fn enrolled_count(&self) -> usize {
        self.enrolled.len()
    }

    pub fn unroutable(&self) -> usize {
        self.unroutable
    }

    pub fn unit_uid(&self, unit: usize) -> u64 {
        self.units[unit].uid
    }

    pub fn is_live(&self, unit: usize) -> bool {
        self.map.is_live(unit)
    }

    /// Identities currently routed to `unit` (its probe target set).
    pub fn assigned_count(&self, unit: usize) -> usize {
        self.assigned[unit].len()
    }

    pub fn id_of(&self, seq: u32) -> &str {
        &self.enrolled[seq as usize].id
    }

    /// The enrolled template bytes for `seq`, read from any resident
    /// replica (replicas are bit-identical by construction).
    pub fn template_of(&self, seq: u32) -> &[f32] {
        let r = self.enrolled[seq as usize].replicas[0];
        self.units[r.unit as usize].index.row(r.row as usize)
    }

    /// Enroll (or update) one identity. With journals attached, the record
    /// is write-ahead appended to *every* replica's journal before this
    /// returns — the caller may only ack on `Ok`.
    pub fn enroll(&mut self, id: &str, template: &[f32]) -> anyhow::Result<u32> {
        self.enroll_inner(id, template, true)
    }

    fn enroll_inner(&mut self, id: &str, template: &[f32], journal: bool) -> anyhow::Result<u32> {
        anyhow::ensure!(template.len() == self.dim, "template dim mismatch");
        // Update path: the id may already be resident (re-enroll refreshes
        // the template in place on every replica).
        for u in 0..self.units.len() {
            if let Some(row) = self.units[u].index.row_of(id) {
                let seq = self.units[u].global_seq[row];
                let replicas = self.enrolled[seq as usize].replicas.clone();
                for r in &replicas {
                    let unit = &mut self.units[r.unit as usize];
                    if journal {
                        if let Some(j) = unit.journal.as_mut() {
                            j.append(id, template)?;
                        }
                    }
                    unit.index.upsert(id, template);
                }
                return Ok(seq);
            }
        }
        let key = placement_key(id);
        // While an expansion is draining, fresh enrolls place on the owner
        // set as it stood before the new unit joined (full replication on
        // units that already hold data) and queue a deferred copy to the
        // newcomer. This keeps every unit's local row order a subsequence
        // of the global enrollment order — the merge tie-break invariant.
        let (owners, defer_to) = match self.rebalance.as_ref() {
            Some(op) if !op.pending.is_empty() => {
                let target = op.target as usize;
                let defer = self.map.owners(key).contains(&target);
                (self.map.owners_excluding(key, target), defer.then_some(op.target))
            }
            _ => (self.map.owners(key), None),
        };
        let seq = u32::try_from(self.enrolled.len()).expect("corpus exceeds u32 sequences");
        // Write-ahead: every replica journal is synced before any index
        // mutation, so an ack never outruns durability on any replica.
        if journal {
            for &u in &owners {
                if let Some(j) = self.units[u].journal.as_mut() {
                    j.append(id, template)?;
                }
            }
        }
        let mut replicas = Vec::with_capacity(owners.len());
        for &u in &owners {
            let unit = &mut self.units[u];
            let row = unit.index.upsert(id, template);
            debug_assert_eq!(row, unit.global_seq.len(), "shard rows must append in order");
            unit.global_seq.push(seq);
            replicas.push(Replica { unit: u as u32, row: row as u32 });
        }
        self.enrolled.push(Enrolled { id: id.to_string(), key, replicas });
        match self.route_of(seq) {
            Some(u) => self.assigned[u].push(seq),
            None => self.unroutable += 1,
        }
        if let Some(target) = defer_to {
            let op = self.rebalance.as_mut().expect("deferral implies an active rebalance");
            op.deferred_offered += 1;
            op.pending.push_back((seq, target, DEFERRED_TID));
        }
        Ok(seq)
    }

    /// Best live resident unit for `seq` — the routing decision.
    fn route_of(&self, seq: u32) -> Option<usize> {
        let e = &self.enrolled[seq as usize];
        let residents: Vec<usize> = e.replicas.iter().map(|r| r.unit as usize).collect();
        self.map.best_live(e.key, &residents)
    }

    /// Recompute every unit's routed set (called on liveness changes).
    /// O(corpus × RF); membership changes are rare, probes are not.
    fn rebuild_routes(&mut self) {
        for a in &mut self.assigned {
            a.clear();
        }
        self.unroutable = 0;
        for seq in 0..self.enrolled.len() as u32 {
            match self.route_of(seq) {
                Some(u) => self.assigned[u].push(seq),
                None => self.unroutable += 1,
            }
        }
    }

    /// Unit detach: mark dead and fall every routed key through to its
    /// next-ranked live replica. Pure metadata — no data moves, nothing is
    /// shed here.
    pub fn detach(&mut self, unit: usize) {
        self.map.set_live(unit, false);
        self.rebuild_routes();
    }

    /// A detached unit returns. Its copies never left, so this too is
    /// metadata-only: routing flips back to rendezvous order.
    pub fn reattach(&mut self, unit: usize) {
        self.map.set_live(unit, true);
        self.rebuild_routes();
    }

    /// Rack an *additional* unit: rendezvous placement re-ranks, and every
    /// identity whose owner set now includes the new unit queues one copy
    /// transfer. Transfers drain through [`Self::rebalance_step`]. Returns
    /// the new unit index.
    pub fn attach_expand(
        &mut self,
        uid: u64,
        journal_key: Option<&str>,
        journal_dir: Option<&Path>,
    ) -> anyhow::Result<usize> {
        anyhow::ensure!(self.rebalance_pending() == 0, "previous rebalance still draining");
        let requested_rf = self.map.replication();
        let unit = self.map.add_unit(uid, requested_rf);
        let journal = match (journal_key, journal_dir) {
            (Some(k), Some(d)) => {
                let (j, recs) = EnrollJournal::open_for_image(
                    &Self::journal_path(d, uid),
                    &SealKey::from_passphrase(k),
                    uid,
                    None,
                )?;
                anyhow::ensure!(recs.is_empty(), "expansion unit must start with an empty journal");
                Some(j)
            }
            _ => None,
        };
        self.units.push(UnitSession {
            uid,
            index: GalleryIndex::new(self.dim),
            global_seq: Vec::new(),
            journal,
        });
        self.assigned.push(Vec::new());

        let mut pending = VecDeque::new();
        for seq in 0..self.enrolled.len() as u32 {
            let e = &self.enrolled[seq as usize];
            if self.map.owners(e.key).contains(&unit)
                && !e.replicas.iter().any(|r| r.unit as usize == unit)
            {
                let tid = pending.len() as u64;
                pending.push_back((seq, unit as u32, tid));
            }
        }
        let total = pending.len() as u64;
        let mut slo = SloTracker::new(total, 1, 1);
        for &(_, _, tid) in &pending {
            slo.offered(&Self::transfer_req(tid));
        }
        self.rebalance = Some(RebalanceOp {
            slo,
            pending,
            total,
            target: unit as u32,
            deferred_offered: 0,
            deferred_done: 0,
        });
        Ok(unit)
    }

    /// The synthetic request a copy transfer is accounted under.
    fn transfer_req(tid: u64) -> Request {
        Request {
            id: tid,
            tenant: 0,
            class: 0,
            kind: RequestKind::Enroll,
            priority: 0,
            arrival_us: 0,
            deadline_us: u64::MAX,
            requeued: false,
        }
    }

    /// Drain up to `max` queued copy transfers at virtual time `now_us`.
    /// Each copies the template from an existing replica, appends to the
    /// target's journal first when one is attached, and flips the key's
    /// routing only once the copy is resident. Returns transfers applied.
    pub fn rebalance_step(&mut self, max: usize, now_us: u64) -> anyhow::Result<usize> {
        let Some(mut op) = self.rebalance.take() else { return Ok(0) };
        let mut moved = 0;
        while moved < max {
            let Some((seq, target, tid)) = op.pending.pop_front() else { break };
            let target = target as usize;
            let template = self.template_of(seq).to_vec();
            let id = self.enrolled[seq as usize].id.clone();
            let unit = &mut self.units[target];
            if let Some(j) = unit.journal.as_mut() {
                j.append(&id, &template)?;
            }
            let row = unit.index.upsert(&id, &template);
            unit.global_seq.push(seq);
            // global_seq stays sorted: transfers enqueue in seq order (the
            // attach-time scan, then deferred enrolls with larger seqs) and
            // drain FIFO into a unit that started empty.
            debug_assert_eq!(row + 1, unit.global_seq.len());
            debug_assert!(unit.global_seq.windows(2).all(|w| w[0] < w[1]));
            let old_route = self.route_of(seq);
            self.enrolled[seq as usize]
                .replicas
                .push(Replica { unit: target as u32, row: row as u32 });
            let new_route = self.route_of(seq);
            if old_route != new_route {
                if let Some(o) = old_route {
                    if let Ok(pos) = self.assigned[o].binary_search(&seq) {
                        self.assigned[o].remove(pos);
                    }
                } else {
                    self.unroutable -= 1;
                }
                if let Some(n) = new_route {
                    if let Err(pos) = self.assigned[n].binary_search(&seq) {
                        self.assigned[n].insert(pos, seq);
                    }
                }
            }
            if tid == DEFERRED_TID {
                op.deferred_done += 1;
            } else {
                op.slo.completed(&Self::transfer_req(tid), now_us);
            }
            moved += 1;
        }
        self.rebalance = Some(op);
        Ok(moved)
    }

    pub fn rebalance_pending(&self) -> usize {
        self.rebalance.as_ref().map(|op| op.pending.len()).unwrap_or(0)
    }

    /// Exactly-once identity over the rebalance stream: every queued copy
    /// is still pending or applied exactly once, with zero state-machine
    /// violations in the tracker. Vacuously true with no expansion.
    pub fn rebalance_accounting_holds(&self) -> bool {
        match &self.rebalance {
            None => true,
            Some(op) => {
                let c = op.slo.class(0);
                let pend_batch =
                    op.pending.iter().filter(|e| e.2 != DEFERRED_TID).count() as u64;
                let pend_def = op.pending.len() as u64 - pend_batch;
                op.slo.violations == 0
                    && c.offered == op.total
                    && c.completed + pend_batch == op.total
                    && op.deferred_done + pend_def == op.deferred_offered
            }
        }
    }

    fn probed_units(&self) -> Vec<usize> {
        (0..self.units.len())
            .filter(|&u| self.map.is_live(u) && !self.assigned[u].is_empty())
            .collect()
    }

    fn pass_stats(&self, batch: usize, k: usize) -> ScatterStats {
        let probed = self.probed_units();
        let per_unit_us: Vec<(u64, u64)> = probed
            .iter()
            .map(|&u| (self.units[u].uid, scan_pass_us(self.assigned[u].len(), self.dim, batch)))
            .collect();
        ScatterStats {
            units_probed: probed.len(),
            scatter_us: SCATTER_BASE_US + SCATTER_PER_UNIT_US * probed.len() as u64,
            probe_wait_us: per_unit_us.iter().map(|&(_, us)| us).max().unwrap_or(0),
            merge_us: MERGE_BASE_US + (k * probed.len()) as u64 / 4,
            per_unit_us,
        }
    }

    /// Virtual cost of one scatter-gather pass scoring `batch` probes at
    /// depth `k` against the current routing: fan-out + the slowest unit's
    /// scan + the bounded heap-merge.
    pub fn fed_pass_us(&self, batch: usize, k: usize) -> u64 {
        self.pass_stats(batch, k).total_us()
    }

    /// Scatter-gather one batch of probes. Each live unit scans its routed
    /// key subset on its own thread (`top_k_rows` — bit-identical to the
    /// covering scan), answers map local rows to global sequences, and the
    /// per-probe gather is [`merge_topk`]. Returns per-probe merged top-k
    /// as `(global sequence, score)` plus the pass cost breakdown.
    pub fn identify_batch(
        &self,
        probes: &[Vec<f32>],
        k: usize,
    ) -> (Vec<Vec<(u32, f32)>>, ScatterStats) {
        let stats = self.pass_stats(probes.len(), k);
        let probed = self.probed_units();
        // One answer list per (unit, probe).
        let per_unit: Vec<Vec<Vec<(usize, f32)>>> = thread::scope(|s| {
            let handles: Vec<_> = probed
                .iter()
                .map(|&u| {
                    let unit = &self.units[u];
                    let assigned = &self.assigned[u];
                    let enrolled = &self.enrolled;
                    s.spawn(move || {
                        let rows: Vec<usize> = assigned
                            .iter()
                            .map(|&seq| {
                                enrolled[seq as usize]
                                    .replicas
                                    .iter()
                                    .find(|r| r.unit as usize == u)
                                    .expect("routed seq without resident replica")
                                    .row as usize
                            })
                            .collect();
                        probes
                            .iter()
                            .map(|p| {
                                unit.index
                                    .top_k_rows(p, rows.iter().copied(), k)
                                    .into_iter()
                                    .map(|(row, score)| (unit.global_seq[row] as usize, score))
                                    .collect::<Vec<_>>()
                            })
                            .collect::<Vec<_>>()
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().expect("unit scan panicked")).collect()
        });
        // Transpose to per-probe lists and merge each deterministically.
        let mut by_probe: Vec<Vec<Vec<(usize, f32)>>> =
            (0..probes.len()).map(|_| Vec::new()).collect();
        for unit_lists in per_unit {
            for (i, l) in unit_lists.into_iter().enumerate() {
                by_probe[i].push(l);
            }
        }
        let merged = by_probe
            .into_iter()
            .map(|lists| {
                merge_topk(lists, k).into_iter().map(|(seq, score)| (seq as u32, score)).collect()
            })
            .collect();
        (merged, stats)
    }

    /// Single-probe convenience over [`Self::identify_batch`].
    pub fn identify(&self, probe: &[f32], k: usize) -> Vec<(u32, f32)> {
        let probes = vec![probe.to_vec()];
        let (mut v, _) = self.identify_batch(&probes, k);
        v.pop().unwrap_or_default()
    }
}

/// Configuration of one federated serving run (virtual time).
#[derive(Debug, Clone)]
pub struct FederationConfig {
    pub profile: MissionProfile,
    pub units: usize,
    pub replication: usize,
    pub seed: u64,
    pub requests: usize,
    pub overload: f64,
    /// Identify probes coalesced per scatter pass.
    pub batch: usize,
    pub gallery: usize,
    pub dim: usize,
    pub k: usize,
    /// Per-unit journal directory: acked enrolls are write-ahead appended
    /// to every replica journal before the ack.
    pub journal_dir: Option<PathBuf>,
    pub journal_key: String,
    pub trace: bool,
    /// Scripted unit-0 detach (physical pull time, virtual us).
    pub detach_at_us: Option<u64>,
    /// Scripted unit-0 re-rack (physical insert time, virtual us).
    pub reattach_at_us: Option<u64>,
}

impl Default for FederationConfig {
    fn default() -> Self {
        FederationConfig {
            profile: MissionProfile::federation(),
            units: 2,
            replication: 2,
            seed: 7,
            requests: 200,
            overload: 2.0,
            batch: 2,
            gallery: 10_000,
            dim: 64,
            k: 10,
            journal_dir: None,
            journal_key: "champ-dev-key".to_string(),
            trace: false,
            detach_at_us: None,
            reattach_at_us: None,
        }
    }
}

/// Outcome of one federated serving run.
#[derive(Debug, Clone)]
pub struct FederationOutcome {
    pub profile_name: &'static str,
    pub units: usize,
    pub replication: usize,
    pub gallery: usize,
    pub dim: usize,
    pub overload: f64,
    pub capacity_rps: f64,
    pub offered_rps: f64,
    pub elapsed_us: u64,
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    pub requeued: u64,
    /// Sheds attributable to the federation failure path: double-eviction
    /// of in-flight work, or a requeued request expiring before its retry
    /// could dispatch. Must be 0 for any single detach at RF ≥ 2.
    pub detach_sheds: u64,
    pub detaches: u32,
    pub reattaches: u32,
    /// Scatter passes executed and merged hits returned (sanity traffic).
    pub scatter_batches: u64,
    pub fed_hits: u64,
    /// Enrolls served live through the router (journal-replicated when
    /// journals are attached).
    pub live_enrolls: u64,
    /// Sum of per-class on-time goodput — the scaling contract's metric.
    pub goodput_rps: f64,
    pub accounting_ok: bool,
    pub classes: Vec<ClassOutcome>,
    pub tenants: Vec<TenantOutcome>,
    pub trace: Option<Vec<TraceRecord>>,
}

enum FEv {
    Arrival(u32),
    MatchDone(u64),
    AuxDone(u64),
    Unit(UnitEvent),
    Tick,
}

/// Drive the federation tier under open-loop traffic in virtual time.
pub fn run(cfg: &FederationConfig) -> anyhow::Result<FederationOutcome> {
    cfg.profile.validate()?;
    anyhow::ensure!(cfg.units >= 1 && cfg.units <= 64, "units must be in 1..=64");
    anyhow::ensure!(cfg.batch >= 1 && cfg.k >= 1 && cfg.gallery >= 1);
    if cfg.detach_at_us.is_some() {
        anyhow::ensure!(
            cfg.units >= 2 && cfg.replication >= 2,
            "a detach script needs >= 2 units at replication >= 2 to lose nothing"
        );
    }

    let uids: Vec<u64> = (0..cfg.units).map(|i| 0x0ACE_0000 + i as u64).collect();
    let mut router = FederationRouter::new(cfg.dim, &uids, cfg.replication);
    if let Some(dir) = &cfg.journal_dir {
        router = router.with_journals(dir, &cfg.journal_key)?;
    }
    // Corpus: identical ids and templates for every unit count, so the
    // scaling sweep compares the same workload.
    let mut grng = Rng::new(cfg.seed ^ 0xfed0_0001);
    for i in router.enrolled_count()..cfg.gallery {
        let v = grng.unit_vec(cfg.dim);
        router.enroll(&format!("id{i}"), &v)?;
    }

    // Capacity calibration against the federated cost model, mirroring the
    // single-unit session: overload 1.0 = what the rack sustains.
    let ident_cost = router.fed_pass_us(1, cfg.k).max(1);
    let ident_cap = 1e6 / ident_cost as f64;
    let aux_cost = ENROLL_BASE_US + JOURNAL_APPEND_US * cfg.replication as u64 + INFER_US;
    let aux_cap = 1e6 / aux_cost as f64;
    let ident_share: f64 = cfg
        .profile
        .classes
        .iter()
        .filter(|c| !c.kind.is_inference())
        .map(|c| c.share)
        .sum();
    let aux_share = 1.0 - ident_share;
    let denom = ident_share / ident_cap + if aux_share > 1e-9 { aux_share / aux_cap } else { 0.0 };
    let capacity_rps = if denom > 0.0 { 1.0 / denom } else { ident_cap };
    let offered_rps = cfg.overload * capacity_rps;

    let reqs = traffic::generate(&cfg.profile, cfg.seed, cfg.requests as u64, offered_rps, 0);
    let n = reqs.len();
    let mut slo = SloTracker::new(n as u64, cfg.profile.classes.len(), cfg.profile.tenants.len());
    let mut adm = AdmissionController::new(&cfg.profile, capacity_rps);
    let rec = if cfg.trace { TraceRecorder::enabled() } else { TraceRecorder::off() };

    let mut q: CompletionQueue<FEv> = CompletionQueue::new();
    for (i, r) in reqs.iter().enumerate() {
        q.push(r.arrival_us, FEv::Arrival(i as u32));
    }
    // Unit hot-swap script: delivered at OS visibility time, independent of
    // the coarse health tick.
    let mut script_events = Vec::new();
    if let Some(at) = cfg.detach_at_us {
        script_events.push(UnitEvent { at_us: at, unit_uid: uids[0], kind: HotplugKind::Detach });
    }
    if let Some(at) = cfg.reattach_at_us {
        script_events.push(UnitEvent { at_us: at, unit_uid: uids[0], kind: HotplugKind::Attach });
    }
    let mut script = UnitScript::new(script_events);
    for e in script.due(u64::MAX) {
        q.push(e.visible_at(), FEv::Unit(e));
    }
    q.push(TICK_US, FEv::Tick);

    // Single match server (the rack behaves as one scatter-gather engine)
    // plus one aux server for the non-sharded classes.
    let mut match_gen: u64 = 0;
    let mut match_inflight: Option<(u64, Vec<Request>)> = None;
    let mut aux_gen: u64 = 0;
    let mut aux_inflight: Option<(u64, Request)> = None;
    let mut expired: Vec<Request> = Vec::new();

    let mut detach_sheds = 0u64;
    let mut detaches = 0u32;
    let mut reattaches = 0u32;
    let mut scatter_batches = 0u64;
    let mut fed_hits = 0u64;
    let mut requeued_total = 0u64;
    let mut live_enrolls = 0u64;

    // Deterministic probe for an identify request: a noisy copy of an
    // enrolled template (same convention as the single-unit session).
    let probe_for = |router: &FederationRouter, id: u64| -> Vec<f32> {
        let mut rng = Rng::new(cfg.seed ^ id.wrapping_mul(0x85eb_ca6b_9e37_79b9));
        if router.enrolled_count() == 0 {
            return rng.unit_vec(cfg.dim);
        }
        let seq = (rng.next_u64() as usize % router.enrolled_count()) as u32;
        router.template_of(seq).iter().map(|v| v + 0.05 * rng.normal()).collect()
    };

    while let Some(ev) = q.pop() {
        let now = ev.at_us;
        match ev.payload {
            FEv::Arrival(i) => {
                let req = reqs[i as usize];
                slo.offered(&req);
                match adm.offer(req, now) {
                    Admission::Admitted => {}
                    Admission::Shed(r) => slo.shed(&req, r, now),
                }
            }
            FEv::MatchDone(gen) => {
                if let Some((g, batch)) = match_inflight.take() {
                    if g == gen {
                        for r in batch {
                            slo.completed(&r, now);
                        }
                    } else {
                        match_inflight = Some((g, batch)); // stale completion of a cancelled pass
                    }
                }
            }
            FEv::AuxDone(gen) => {
                if let Some((g, r)) = aux_inflight.take() {
                    if g == gen {
                        slo.completed(&r, now);
                    } else {
                        aux_inflight = Some((g, r));
                    }
                }
            }
            FEv::Unit(e) => {
                let unit = uids.iter().position(|&u| u == e.unit_uid).expect("scripted uid");
                match e.kind {
                    HotplugKind::Detach => {
                        router.detach(unit);
                        detaches += 1;
                        // In-flight scatter work touched the lost unit:
                        // requeue exactly once, never silently drop.
                        if let Some((_, batch)) = match_inflight.take() {
                            match_gen += 1; // stale-ify the pending MatchDone
                            for mut r in batch {
                                if r.requeued {
                                    slo.shed(&r, ShedReason::Evicted, now);
                                    detach_sheds += 1;
                                } else {
                                    r.requeued = true;
                                    slo.requeued(&r);
                                    requeued_total += 1;
                                    adm.requeue(r);
                                }
                            }
                        }
                    }
                    HotplugKind::Attach => {
                        router.reattach(unit);
                        reattaches += 1;
                    }
                }
            }
            FEv::Tick => {
                adm.expire_overdue(now, &mut expired);
                if router.rebalance_pending() > 0 {
                    router.rebalance_step(REBALANCE_BATCH, now)?;
                }
                if slo.terminal_count < n as u64 {
                    q.push(now + TICK_US, FEv::Tick);
                }
            }
        }

        // Shed everything that expired in queue (federation-attributed iff
        // a detach had already requeued it).
        for r in expired.drain(..) {
            if r.requeued {
                detach_sheds += 1;
            }
            slo.shed(&r, ShedReason::Expired, now);
        }

        // Pump the match server: coalesce up to `batch` Identify requests
        // into one scatter-gather pass.
        if match_inflight.is_none() {
            let est = router.fed_pass_us(cfg.batch, cfg.k);
            let mut batch: Vec<Request> = Vec::with_capacity(cfg.batch);
            while batch.len() < cfg.batch {
                match adm.pop_dispatchable(now, false, est, &mut expired) {
                    Some(r) => batch.push(r),
                    None => break,
                }
            }
            for r in expired.drain(..) {
                if r.requeued {
                    detach_sheds += 1;
                }
                slo.shed(&r, ShedReason::Expired, now);
            }
            if !batch.is_empty() {
                let probes: Vec<Vec<f32>> =
                    batch.iter().map(|r| probe_for(&router, r.id)).collect();
                let (hits, stats) = router.identify_batch(&probes, cfg.k);
                fed_hits += hits.iter().map(|h| h.len() as u64).sum::<u64>();
                scatter_batches += 1;
                let t_scatter = now + stats.scatter_us;
                let t_gather = t_scatter + stats.probe_wait_us;
                let t_done = t_gather + stats.merge_us;
                for r in &batch {
                    let tid = TraceId::request(r.id);
                    rec.span(tid, Stage::Scatter, now, t_scatter, stats.units_probed as u64, 0);
                    for &(uid, us) in &stats.per_unit_us {
                        rec.span(tid, Stage::ProbeWait, t_scatter, t_scatter + us, uid, 0);
                    }
                    rec.span(tid, Stage::Merge, t_gather, t_done, cfg.k as u64, 0);
                }
                match_gen += 1;
                match_inflight = Some((match_gen, batch));
                q.push(t_done, FEv::MatchDone(match_gen));
            }
        }

        // Pump the aux server: one Enroll/ArtifactRun at a time.
        if aux_inflight.is_none() {
            if let Some(r) = adm.pop_dispatchable(now, true, aux_cost, &mut expired) {
                if r.kind == RequestKind::Enroll {
                    // A served enroll is a *real* federated enroll: the ack
                    // (completion) is only scheduled because every replica
                    // journal append succeeded write-ahead.
                    let template = {
                        let mut rng =
                            Rng::new(cfg.seed ^ r.id.wrapping_mul(0xc2b2_ae3d_27d4_eb4f));
                        rng.unit_vec(cfg.dim)
                    };
                    router.enroll(&format!("live-{}", r.id), &template)?;
                    live_enrolls += 1;
                }
                aux_gen += 1;
                aux_inflight = Some((aux_gen, r));
                q.push(now + aux_cost, FEv::AuxDone(aux_gen));
            }
            for r in expired.drain(..) {
                if r.requeued {
                    detach_sheds += 1;
                }
                slo.shed(&r, ShedReason::Expired, now);
            }
        }
    }

    let elapsed = slo.last_terminal_us.max(1);
    let classes = slo.summarize(&cfg.profile, elapsed);
    let tenants = slo.summarize_tenants(&cfg.profile, elapsed);
    let offered: u64 = classes.iter().map(|c| c.offered).sum();
    let completed: u64 = classes.iter().map(|c| c.completed).sum();
    let shed: u64 = classes.iter().map(|c| c.shed).sum();
    let goodput_rps: f64 = classes.iter().map(|c| c.goodput_rps).sum();
    Ok(FederationOutcome {
        profile_name: cfg.profile.name,
        units: cfg.units,
        replication: router.replication(),
        gallery: cfg.gallery,
        dim: cfg.dim,
        overload: cfg.overload,
        capacity_rps,
        offered_rps,
        elapsed_us: elapsed,
        offered,
        completed,
        shed,
        requeued: requeued_total,
        detach_sheds,
        detaches,
        reattaches,
        scatter_batches,
        fed_hits,
        live_enrolls,
        goodput_rps,
        accounting_ok: slo.accounting_holds() && router.rebalance_accounting_holds(),
        classes,
        tenants,
        trace: if cfg.trace { Some(rec.snapshot()) } else { None },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus_router(
        n: usize,
        units: usize,
        rf: usize,
        dim: usize,
    ) -> (FederationRouter, GalleryIndex) {
        let uids: Vec<u64> = (0..units).map(|i| 0x0ACE_0000 + i as u64).collect();
        let mut router = FederationRouter::new(dim, &uids, rf);
        let mut union = GalleryIndex::new(dim);
        let mut rng = Rng::new(0xfed0_0001 ^ 7);
        for i in 0..n {
            let v = rng.unit_vec(dim);
            let seq = router.enroll(&format!("id{i}"), &v).unwrap();
            assert_eq!(seq as usize, union.upsert(format!("id{i}"), &v));
        }
        (router, union)
    }

    #[test]
    fn federated_identify_is_bit_identical_to_union_scan() {
        let (router, union) = corpus_router(600, 3, 2, 16);
        let mut rng = Rng::new(99);
        for _ in 0..20 {
            let probe = rng.unit_vec(16);
            let fed = router.identify(&probe, 10);
            let oracle = union.top_k(&probe, 10);
            assert_eq!(fed.len(), oracle.len());
            for (f, o) in fed.iter().zip(&oracle) {
                assert_eq!(f.0 as usize, o.0);
                assert_eq!(f.1.to_bits(), o.1.to_bits());
            }
        }
    }

    #[test]
    fn detach_keeps_answers_bit_identical_at_rf2() {
        let (mut router, union) = corpus_router(400, 3, 2, 16);
        let mut rng = Rng::new(41);
        let probe = rng.unit_vec(16);
        let before = router.identify(&probe, 8);
        router.detach(0);
        assert_eq!(router.unroutable(), 0, "RF=2 covers any single loss");
        let after = router.identify(&probe, 8);
        assert_eq!(before, after);
        let oracle = union.top_k(&probe, 8);
        for (f, o) in after.iter().zip(&oracle) {
            assert_eq!((f.0 as usize, f.1.to_bits()), (o.0, o.1.to_bits()));
        }
        router.reattach(0);
        assert_eq!(router.identify(&probe, 8), before);
    }

    #[test]
    fn routed_sets_partition_the_corpus() {
        let (mut router, _) = corpus_router(500, 4, 2, 8);
        let total: usize = (0..4).map(|u| router.assigned_count(u)).sum();
        assert_eq!(total + router.unroutable(), 500);
        router.detach(2);
        assert_eq!(router.assigned_count(2), 0);
        let total: usize = (0..4).map(|u| router.assigned_count(u)).sum();
        assert_eq!(total, 500);
    }

    #[test]
    fn scatter_cost_shrinks_with_unit_count() {
        // At this corpus size the fixed per-pass overheads still bite; the
        // full >=1.7x / >=3.0x contract is CI-gated at the 1M corpus where
        // they amortize away.
        let mk = |units: usize| {
            let (router, _) = corpus_router(64_000, units, units.min(2), 32);
            router.fed_pass_us(2, 10)
        };
        let one = mk(1);
        let two = mk(2);
        let four = mk(4);
        assert!(two < one && four < two, "cost must fall with units: {one} {two} {four}");
        assert!(one as f64 / two as f64 > 1.5, "2 units: {one} vs {two}");
        assert!(one as f64 / four as f64 > 2.0, "4 units: {one} vs {four}");
    }

    #[test]
    fn expansion_rebalances_incrementally_and_exactly_once() {
        let (mut router, _) = corpus_router(300, 2, 2, 8);
        let new_unit = router.attach_expand(0x0ACE_00FF, None, None).unwrap();
        let queued = router.rebalance_pending();
        assert!(queued > 0 && queued < 300, "expansion moves a strict subset, got {queued}");
        assert!(router.rebalance_accounting_holds(), "nothing lost while pending");
        let mut steps = 0u64;
        while router.rebalance_pending() > 0 {
            let moved = router.rebalance_step(32, 1_000 * steps).unwrap();
            assert!(moved > 0 && moved <= 32);
            assert!(router.rebalance_accounting_holds(), "holds at every step");
            steps += 1;
        }
        assert!(steps > 1, "32-per-step drain must take multiple steps");
        assert!(router.assigned_count(new_unit) > 0, "new unit serves after rebalance");
        let total: usize = (0..router.unit_count()).map(|u| router.assigned_count(u)).sum();
        assert_eq!(total, 300);
    }

    #[test]
    fn enroll_during_rebalance_defers_and_stays_bit_identical() {
        let (mut router, mut union) = corpus_router(200, 2, 2, 8);
        router.attach_expand(0x0ACE_00FF, None, None).unwrap();
        let mut rng = Rng::new(5);
        // New enrolls land mid-drain: placement defers the newcomer copy.
        for i in 0..40 {
            let v = rng.unit_vec(8);
            router.enroll(&format!("mid{i}"), &v).unwrap();
            union.upsert(format!("mid{i}"), &v);
        }
        let mut t = 0;
        while router.rebalance_pending() > 0 {
            router.rebalance_step(16, t).unwrap();
            assert!(router.rebalance_accounting_holds());
            t += 1_000;
        }
        let probe = rng.unit_vec(8);
        let fed = router.identify(&probe, 12);
        let oracle = union.top_k(&probe, 12);
        for (f, o) in fed.iter().zip(&oracle) {
            assert_eq!((f.0 as usize, f.1.to_bits()), (o.0, o.1.to_bits()));
        }
    }

    #[test]
    fn run_is_deterministic_and_accounts_exactly_once() {
        let cfg = FederationConfig {
            gallery: 2_000,
            dim: 16,
            requests: 120,
            ..FederationConfig::default()
        };
        let a = run(&cfg).unwrap();
        let b = run(&cfg).unwrap();
        assert!(a.accounting_ok);
        assert_eq!(a.offered, a.completed + a.shed);
        assert_eq!(
            (a.offered, a.completed, a.shed, a.fed_hits, a.scatter_batches, a.elapsed_us),
            (b.offered, b.completed, b.shed, b.fed_hits, b.scatter_batches, b.elapsed_us)
        );
        assert!(a.completed > 0 && a.fed_hits > 0);
    }

    #[test]
    fn detach_under_load_sheds_nothing_at_rf2() {
        let cfg = FederationConfig {
            gallery: 2_000,
            dim: 16,
            requests: 150,
            detach_at_us: Some(5_000),
            ..FederationConfig::default()
        };
        let out = run(&cfg).unwrap();
        assert_eq!(out.detaches, 1);
        assert!(out.requeued >= 1, "the detach must catch work in flight");
        assert_eq!(out.detach_sheds, 0, "RF=2 must absorb a single unit loss");
        assert!(out.accounting_ok);
        assert_eq!(out.offered, out.completed + out.shed);
    }

    #[test]
    fn federation_spans_tile_scatter_probe_merge() {
        use crate::obs::recorder::RecordKind;
        let cfg = FederationConfig {
            gallery: 1_000,
            dim: 16,
            requests: 40,
            trace: true,
            ..FederationConfig::default()
        };
        let out = run(&cfg).unwrap();
        let spans = out.trace.unwrap();
        let scatter: Vec<_> = spans
            .iter()
            .filter(|r| r.kind == RecordKind::Span(Stage::Scatter))
            .collect();
        assert!(!scatter.is_empty());
        for s in &scatter {
            let pw = spans
                .iter()
                .find(|r| {
                    r.trace == s.trace
                        && r.kind == RecordKind::Span(Stage::ProbeWait)
                        && r.t0_us == s.t1_us
                })
                .expect("every scatter is followed by a probe-wait tile");
            let m = spans
                .iter()
                .find(|r| {
                    r.trace == s.trace
                        && r.kind == RecordKind::Span(Stage::Merge)
                        && r.t0_us >= pw.t0_us
                })
                .expect("every scatter ends in a merge tile");
            assert!(m.t0_us >= s.t1_us, "merge starts after scatter ends");
        }
    }

    #[test]
    fn untraced_run_is_bit_identical_to_traced() {
        let base =
            FederationConfig { gallery: 1_500, dim: 16, requests: 80, ..Default::default() };
        let traced = run(&FederationConfig { trace: true, ..base.clone() }).unwrap();
        let plain = run(&base).unwrap();
        assert_eq!(
            (traced.offered, traced.completed, traced.shed, traced.fed_hits, traced.elapsed_us),
            (plain.offered, plain.completed, plain.shed, plain.fed_hits, plain.elapsed_us)
        );
    }
}
