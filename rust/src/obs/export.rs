//! Trace exporters: Chrome/Perfetto trace-event JSON and folded stacks.
//!
//! The Perfetto format is the trace-event JSON object form — a top-level
//! `{"traceEvents": [...]}` with `ph:"X"` complete events (`ts`/`dur` in
//! microseconds, which is exactly our virtual clock unit) and `ph:"i"`
//! instants.  `chrome://tracing` and <https://ui.perfetto.dev> both load
//! it; extra top-level keys (we add `"metrics"`) are tolerated by spec.
//!
//! Tracks: `pid` groups records into three processes — requests, engine
//! frames, storage — and `tid` is the trace id within its group, so one
//! request's admission → queue → dispatch → bus-grant → compute → unseal
//! chain renders as one row of tiled slices.
//!
//! Folded stacks are the `inferno`/FlameGraph text format: one
//! `stack;frames count` line per aggregate, here `<group>;<stage>` with
//! the summed span microseconds as the count, so any stock flamegraph
//! tool renders where the virtual time went.

use crate::json::{self, num, obj, s, Value};

use super::recorder::{RecordKind, TraceId, TraceRecord};
use super::TraceSnapshot;

/// Perfetto `pid` for serving-request tracks.
const PID_REQUESTS: u64 = 1;
/// Perfetto `pid` for engine device-frame tracks.
const PID_ENGINE: u64 = 2;
/// Perfetto `pid` for the storage track (mounts, unseal waves).
const PID_STORAGE: u64 = 3;

fn group_of(t: TraceId) -> (u64, u64) {
    if t == TraceId::STORAGE {
        (PID_STORAGE, 0)
    } else if t.is_frame() {
        (PID_ENGINE, t.0 & 0x00FF_FFFF_FFFF_FFFF)
    } else {
        (PID_REQUESTS, t.0)
    }
}

fn meta_event(pid: u64, name: &str) -> Value {
    obj(vec![
        ("ph", s("M")),
        ("pid", num(pid as f64)),
        ("tid", num(0.0)),
        ("name", s("process_name")),
        ("args", obj(vec![("name", s(name))])),
    ])
}

fn record_event(r: &TraceRecord) -> Value {
    let (pid, tid) = group_of(r.trace);
    let args = obj(vec![("a", num(r.a as f64)), ("b", num(r.b as f64))]);
    match r.kind {
        RecordKind::Span(stage) => obj(vec![
            ("ph", s("X")),
            ("name", s(stage.as_str())),
            ("cat", s("champ")),
            ("pid", num(pid as f64)),
            ("tid", num(tid as f64)),
            ("ts", num(r.t0_us as f64)),
            ("dur", num(r.dur_us() as f64)),
            ("args", args),
        ]),
        RecordKind::Event(kind) => obj(vec![
            ("ph", s("i")),
            ("name", s(kind.as_str())),
            ("cat", s("champ")),
            ("pid", num(pid as f64)),
            ("tid", num(tid as f64)),
            ("ts", num(r.t0_us as f64)),
            ("s", s("t")),
            ("args", args),
        ]),
    }
}

fn metrics_value(snap: &TraceSnapshot) -> Value {
    let counters: Vec<(String, Value)> =
        snap.metrics.counters.iter().map(|(k, v)| (k.clone(), num(*v as f64))).collect();
    let gauges: Vec<(String, Value)> = snap
        .metrics
        .gauges
        .iter()
        .map(|(k, last, max)| {
            (k.clone(), obj(vec![("last", num(*last as f64)), ("max", num(*max as f64))]))
        })
        .collect();
    let hists: Vec<(String, Value)> = snap
        .metrics
        .hists
        .iter()
        .map(|(k, h)| {
            (
                k.clone(),
                obj(vec![
                    ("count", num(h.count as f64)),
                    ("mean_us", num(h.mean_us as f64)),
                    ("p50_us", num(h.p50_us as f64)),
                    ("p99_us", num(h.p99_us as f64)),
                    ("max_us", num(h.max_us as f64)),
                ]),
            )
        })
        .collect();
    obj(vec![
        ("counters", Value::Obj(counters)),
        ("gauges", Value::Obj(gauges)),
        ("histograms", Value::Obj(hists)),
        ("dropped_records", num(snap.dropped as f64)),
    ])
}

/// The full snapshot as a Perfetto-loadable trace-event JSON [`Value`].
pub fn perfetto_value(snap: &TraceSnapshot) -> Value {
    let mut events = vec![
        meta_event(PID_REQUESTS, "requests"),
        meta_event(PID_ENGINE, "engine"),
        meta_event(PID_STORAGE, "storage"),
    ];
    events.extend(snap.records.iter().map(record_event));
    obj(vec![
        ("traceEvents", Value::Arr(events)),
        ("displayTimeUnit", s("ms")),
        ("metrics", metrics_value(snap)),
    ])
}

/// Pretty-printed Perfetto trace-event JSON.
pub fn perfetto_json(snap: &TraceSnapshot) -> String {
    perfetto_value(snap).to_json_pretty()
}

/// Folded-stacks flamegraph text: `group;stage total_span_us` lines,
/// stage-sorted within each group.  Instants are excluded (zero width).
pub fn folded_stacks(snap: &TraceSnapshot) -> String {
    // (group name, stage) -> summed span microseconds.  Small fixed key
    // space, so a sorted Vec beats a map for determinism and simplicity.
    let mut totals: Vec<((&'static str, &'static str), u64)> = Vec::new();
    for r in &snap.records {
        let RecordKind::Span(stage) = r.kind else { continue };
        let group = match group_of(r.trace).0 {
            PID_ENGINE => "engine",
            PID_STORAGE => "storage",
            _ => "requests",
        };
        let key = (group, stage.as_str());
        match totals.iter_mut().find(|(k, _)| *k == key) {
            Some((_, v)) => *v += r.dur_us(),
            None => totals.push((key, r.dur_us())),
        }
    }
    totals.sort_unstable_by_key(|(k, _)| *k);
    let mut out = String::new();
    for ((group, stage), us) in totals {
        out.push_str(group);
        out.push(';');
        out.push_str(stage);
        out.push(' ');
        out.push_str(&us.to_string());
        out.push('\n');
    }
    out
}

/// Parse helper used by tests and the CLI overhead gate: the number of
/// `traceEvents` entries in an exported Perfetto JSON string.
pub fn count_trace_events(text: &str) -> Result<usize, json::ParseError> {
    let v = json::parse(text)?;
    Ok(v.get("traceEvents").and_then(Value::as_arr).map(|a| a.len()).unwrap_or(0))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::{EventKind, Stage, TraceRecorder};

    fn sample_snapshot() -> TraceSnapshot {
        let rec = TraceRecorder::enabled();
        rec.event(TraceId::request(1), EventKind::Offered, 100, 0, 0);
        rec.span(TraceId::request(1), Stage::Queue, 100, 250, 0, 0);
        rec.span(TraceId::request(1), Stage::Compute, 300, 900, 2, 0);
        rec.span(TraceId::frame(4), Stage::Wire, 50, 80, 0, 0);
        rec.span(TraceId::STORAGE, Stage::UnsealWave, 0, 0, 8, 8);
        let reg = crate::obs::MetricsRegistry::new();
        reg.count("serve.offered", 5);
        reg.gauge("serve.queue_depth", 3);
        reg.observe("serve.latency_us", 800);
        TraceSnapshot { records: rec.snapshot(), metrics: reg.snapshot(), dropped: 0 }
    }

    #[test]
    fn perfetto_output_parses_and_has_trace_events() {
        let snap = sample_snapshot();
        let text = perfetto_json(&snap);
        // 3 process_name metadata events + 5 records.
        assert_eq!(count_trace_events(&text).unwrap(), 8);
        let v = crate::json::parse(&text).unwrap();
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        // Every non-metadata event carries ph/pid/tid/ts.
        for e in events {
            assert!(e.get("ph").is_some());
            assert!(e.get("pid").is_some());
            assert!(e.get("ts").is_some() || e.get("ph").unwrap().as_str() == Some("M"));
        }
        // The queue span landed in the requests process with its duration.
        let span = events
            .iter()
            .find(|e| e.get("name").and_then(Value::as_str) == Some("queue"))
            .unwrap();
        assert_eq!(span.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(span.get("pid").unwrap().as_u64(), Some(1));
        assert_eq!(span.get("dur").unwrap().as_u64(), Some(150));
        // Metrics rode along as a tolerated extra key.
        assert_eq!(
            v.get("metrics").unwrap().get("counters").unwrap().get("serve.offered").unwrap()
                .as_u64(),
            Some(5)
        );
    }

    #[test]
    fn tracks_split_by_id_band() {
        let snap = sample_snapshot();
        let v = perfetto_value(&snap);
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        let pid_of = |name: &str| {
            events
                .iter()
                .find(|e| e.get("name").and_then(Value::as_str) == Some(name))
                .and_then(|e| e.get("pid"))
                .and_then(Value::as_u64)
                .unwrap()
        };
        assert_eq!(pid_of("queue"), PID_REQUESTS);
        assert_eq!(pid_of("wire"), PID_ENGINE);
        assert_eq!(pid_of("unseal-wave"), PID_STORAGE);
    }

    #[test]
    fn folded_stacks_aggregate_span_time() {
        let snap = sample_snapshot();
        let text = folded_stacks(&snap);
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines.contains(&"requests;queue 150"));
        assert!(lines.contains(&"requests;compute 600"));
        assert!(lines.contains(&"engine;wire 30"));
        assert!(lines.contains(&"storage;unseal-wave 0"));
        // Instants contribute no lines.
        assert!(!text.contains("offered"));
        // Deterministic order: sorted by (group, stage).
        let mut sorted = lines.clone();
        sorted.sort_unstable();
        assert_eq!(lines, sorted);
    }

    #[test]
    fn empty_snapshot_exports_cleanly() {
        let snap = TraceSnapshot::default();
        let text = perfetto_json(&snap);
        assert_eq!(count_trace_events(&text).unwrap(), 3);
        assert_eq!(folded_stacks(&snap), "");
    }
}
