//! The flight recorder: an always-on, bounded, crash-persistent black
//! box for the serve plane (DESIGN.md §Flight recorder & anomaly
//! detection).
//!
//! Tracing (`--trace`) is opt-in and verbose; the flight recorder is the
//! opposite trade: a single small overwrite-oldest ring of recent
//! span/event/metric-sample records that costs nothing when disarmed
//! (same `Option<Arc<_>>` niche discipline as
//! [`TraceRecorder`](super::TraceRecorder)) and, when armed, persists
//! itself *only* when something goes wrong.  On a trigger —
//! shed-rate spike, deadline-miss burst, health eviction, journal stall,
//! or panic (via [`install_panic_hook`]) — the ring is sealed and dumped
//! to a sidecar `.bbx` file that `champd monitor` can decode after the
//! fact, even if the process never got to print a report.
//!
//! ## Dump format (`.bbx`)
//!
//! ```text
//! +------------------------------+ 0
//! | file header (24 B)           |  magic "CHAMPBBX" | u32 version |
//! +------------------------------+  u32 reserved | u64 seed
//! | frame 0: trigger metadata    |  sealed frames, magic "BBX1",
//! | frame 1: record batch        |  same 24-B header + CTR+HMAC body
//! | ...                          |  as the enrollment journal
//! +------------------------------+  (vdisk frame codec, shared)
//! ```
//!
//! Frames reuse the [`crate::vdisk::frames`] codec: subkeys are bound to
//! `champ/flight/{seed}/{seq}/{nonce}` with a content-derived nonce, so
//! a dump for a given seed and ring content is **byte-identical** across
//! runs (the obs-effect tests pin this down), splicing frames between
//! dumps fails the MAC, and a dump torn by the very crash it was
//! recording decodes to a valid truncated prefix rather than an error.
//!
//! Frame 0 is 32 bytes of trigger metadata (`trigger | pad ×7 | u64 t_us
//! | u64 detail | u64 record_count`); frames 1..N carry batches of up to
//! 256 records, each 48 bytes LE (`kind_code | pad ×7 | u64 trace | u64
//! t0 | u64 t1 | u64 a | u64 b`).  `kind_code` shares the
//! [`RecordKind::code`] namespace: spans `0x00..=0x3F`, events
//! `0x40..=0x7F`, and `0x80 | SeriesId` for metric samples that exist
//! only in the flight ring.
//!
//! **First trigger wins**: the dump latches, later triggers are no-ops —
//! the interesting state is the ring *at the first fault*, and a
//! deterministic file beats a last-writer race.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::crypto::seal::SealKey;
use crate::vdisk::frames;

use super::detect::SeriesId;
use super::recorder::{EventKind, RecordKind, Stage, TraceId, TraceRecord};

/// Sidecar dump file magic.
pub const FLIGHT_MAGIC: [u8; 8] = *b"CHAMPBBX";
/// Dump format revision.
pub const FLIGHT_VERSION: u32 = 1;
/// File header: magic(8) + version(4) + reserved(4) + seed(8).
const FILE_HDR_LEN: usize = 24;
/// Sealed-frame magic inside a dump.
const FRAME_MAGIC: [u8; 4] = *b"BBX1";
/// Domain string mixed into the content-derived frame nonce.
const NONCE_DOMAIN: &[u8] = b"champ-flight-nonce-v1";
/// Records retained before the ring overwrites its oldest.
pub const RING_CAP: usize = 4096;
/// Records per sealed batch frame.
const BATCH: usize = 256;
/// Trigger-metadata payload length (frame 0).
const TRIGGER_LEN: usize = 32;

/// Subkey tweak binding a dump frame to (seed, seq, content nonce).
fn flight_tweak(seed: u64, seq: u64, nonce: u64) -> String {
    format!("champ/flight/{seed}/{seq}/{nonce:016x}")
}

/// Why the black box dumped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum FlightTrigger {
    /// Shed-rate spike detected by the anomaly engine.
    ShedSpike = 0,
    /// Deadline-miss burn-rate alert.
    DeadlineMissBurst = 1,
    /// HealthMonitor evicted in-flight work.
    Eviction = 2,
    /// The enrollment journal stalled (fail-safe shedding engaged).
    JournalStalled = 3,
    /// Process panic (via [`install_panic_hook`]).
    Panic = 4,
    /// Operator- or test-requested dump.
    Manual = 5,
}

impl FlightTrigger {
    pub fn as_str(&self) -> &'static str {
        match self {
            FlightTrigger::ShedSpike => "shed-spike",
            FlightTrigger::DeadlineMissBurst => "deadline-miss-burst",
            FlightTrigger::Eviction => "eviction",
            FlightTrigger::JournalStalled => "journal-stalled",
            FlightTrigger::Panic => "panic",
            FlightTrigger::Manual => "manual",
        }
    }

    pub fn from_code(c: u8) -> Option<FlightTrigger> {
        Some(match c {
            0 => FlightTrigger::ShedSpike,
            1 => FlightTrigger::DeadlineMissBurst,
            2 => FlightTrigger::Eviction,
            3 => FlightTrigger::JournalStalled,
            4 => FlightTrigger::Panic,
            5 => FlightTrigger::Manual,
            _ => return None,
        })
    }
}

/// One flight-ring record: 48 bytes LE on the wire.  `kind_code` shares
/// the trace [`RecordKind::code`] namespace, extended with
/// `0x80 | SeriesId` for metric samples.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlightRecord {
    pub kind_code: u8,
    pub trace: u64,
    pub t0_us: u64,
    pub t1_us: u64,
    pub a: u64,
    pub b: u64,
}

impl FlightRecord {
    pub const WIRE_LEN: usize = 48;

    fn encode(&self) -> [u8; Self::WIRE_LEN] {
        let mut w = [0u8; Self::WIRE_LEN];
        w[0] = self.kind_code;
        w[8..16].copy_from_slice(&self.trace.to_le_bytes());
        w[16..24].copy_from_slice(&self.t0_us.to_le_bytes());
        w[24..32].copy_from_slice(&self.t1_us.to_le_bytes());
        w[32..40].copy_from_slice(&self.a.to_le_bytes());
        w[40..48].copy_from_slice(&self.b.to_le_bytes());
        w
    }

    fn decode(w: &[u8]) -> Option<FlightRecord> {
        if w.len() != Self::WIRE_LEN {
            return None;
        }
        Some(FlightRecord {
            kind_code: w[0],
            trace: u64::from_le_bytes(w[8..16].try_into().unwrap()),
            t0_us: u64::from_le_bytes(w[16..24].try_into().unwrap()),
            t1_us: u64::from_le_bytes(w[24..32].try_into().unwrap()),
            a: u64::from_le_bytes(w[32..40].try_into().unwrap()),
            b: u64::from_le_bytes(w[40..48].try_into().unwrap()),
        })
    }

    /// This record as a trace record, when it is a span or event.
    pub fn as_trace_record(&self) -> Option<TraceRecord> {
        Some(TraceRecord {
            trace: TraceId(self.trace),
            kind: RecordKind::from_code(self.kind_code)?,
            t0_us: self.t0_us,
            t1_us: self.t1_us,
            a: self.a,
            b: self.b,
        })
    }

    /// The series id, when this record is a metric sample (`b` unused,
    /// `a` carries the value as `f64::to_bits`).
    pub fn series(&self) -> Option<SeriesId> {
        if self.kind_code & 0x80 != 0 {
            SeriesId::from_code(self.kind_code & 0x7F)
        } else {
            None
        }
    }

    /// Human label for monitor output.
    pub fn kind_str(&self) -> String {
        if let Some(s) = self.series() {
            format!("sample:{}", s.as_str())
        } else if let Some(k) = RecordKind::from_code(self.kind_code) {
            k.as_str().to_string()
        } else {
            format!("unknown:{:#04x}", self.kind_code)
        }
    }
}

/// Fixed-capacity overwrite ring (single lock: writers are the
/// single-threaded virtual-time event loop, so there is no contention to
/// shard away, and one ring keeps dump order globally chronological).
struct Ring {
    buf: Vec<FlightRecord>,
    head: usize,
    wrapped: bool,
}

impl Ring {
    fn new() -> Self {
        Ring { buf: Vec::new(), head: 0, wrapped: false }
    }

    fn push(&mut self, r: FlightRecord) -> bool {
        if self.wrapped {
            self.buf[self.head] = r;
            self.head = (self.head + 1) % RING_CAP;
            return true;
        }
        self.buf.push(r);
        if self.buf.len() == RING_CAP {
            self.wrapped = true;
        }
        false
    }

    /// Retained records, oldest first.
    fn snapshot(&self) -> Vec<FlightRecord> {
        if !self.wrapped {
            return self.buf.clone();
        }
        let mut out = Vec::with_capacity(RING_CAP);
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }
}

struct FlightCore {
    seed: u64,
    key: SealKey,
    sidecar: PathBuf,
    ring: Mutex<Ring>,
    vnow: AtomicU64,
    dropped: AtomicU64,
    dumped: AtomicBool,
}

/// The flight-recorder handle: cheap to clone, `off()` is free to call
/// into (every method is an `#[inline]` early return when disarmed).
#[derive(Clone, Default)]
pub struct FlightRecorder(Option<Arc<FlightCore>>);

impl FlightRecorder {
    /// The disarmed recorder as a `const` (compile-time no-op path).
    pub const OFF: FlightRecorder = FlightRecorder(None);

    /// A recorder that records nothing and allocates nothing.
    pub fn off() -> Self {
        FlightRecorder(None)
    }

    /// Arm the black box: ring in memory, sealed dump to `sidecar` on
    /// the first trigger.  `seed` binds the dump's subkeys (and is
    /// stored in the header) so same-seed dumps are byte-identical.
    pub fn armed(seed: u64, key: SealKey, sidecar: PathBuf) -> Self {
        FlightRecorder(Some(Arc::new(FlightCore {
            seed,
            key,
            sidecar,
            ring: Mutex::new(Ring::new()),
            vnow: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            dumped: AtomicBool::new(false),
        })))
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Publish the event loop's virtual time (stamped into the trigger
    /// frame at dump time).
    #[inline]
    pub fn set_vnow(&self, t_us: u64) {
        if let Some(core) = &self.0 {
            core.vnow.store(t_us, Ordering::Relaxed);
        }
    }

    /// Last published virtual time (0 when disarmed).
    #[inline]
    pub fn vnow(&self) -> u64 {
        self.0.as_ref().map(|c| c.vnow.load(Ordering::Relaxed)).unwrap_or(0)
    }

    #[inline]
    fn push(&self, r: FlightRecord) {
        let Some(core) = &self.0 else { return };
        let overwrote = core.ring.lock().unwrap().push(r);
        if overwrote {
            core.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a completed span `[t0, t1]`.
    #[inline]
    pub fn span(&self, trace: TraceId, stage: Stage, t0_us: u64, t1_us: u64, a: u64, b: u64) {
        if self.0.is_none() {
            return;
        }
        self.push(FlightRecord {
            kind_code: RecordKind::Span(stage).code(),
            trace: trace.0,
            t0_us,
            t1_us,
            a,
            b,
        });
    }

    /// Record an instant event at `t`.
    #[inline]
    pub fn event(&self, trace: TraceId, kind: EventKind, t_us: u64, a: u64, b: u64) {
        if self.0.is_none() {
            return;
        }
        self.push(FlightRecord {
            kind_code: RecordKind::Event(kind).code(),
            trace: trace.0,
            t0_us: t_us,
            t1_us: t_us,
            a,
            b,
        });
    }

    /// Record one metric sample (`value` kept as `f64::to_bits`).
    #[inline]
    pub fn sample(&self, series: SeriesId, t_us: u64, value: f64) {
        if self.0.is_none() {
            return;
        }
        self.push(FlightRecord {
            kind_code: 0x80 | series as u8,
            trace: TraceId::STORAGE.0,
            t0_us: t_us,
            t1_us: t_us,
            a: value.to_bits(),
            b: 0,
        });
    }

    /// Records overwritten by ring overflow since arming.
    pub fn dropped(&self) -> u64 {
        self.0.as_ref().map(|c| c.dropped.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// True once a trigger has latched the dump.
    pub fn dumped(&self) -> bool {
        self.0.as_ref().map(|c| c.dumped.load(Ordering::Relaxed)).unwrap_or(false)
    }

    /// Seal the ring and persist it to the sidecar.  First trigger wins:
    /// returns the dump path on the winning call, `None` when disarmed,
    /// already dumped, or the write failed (the failure is reported on
    /// stderr but never panics — this runs inside the panic hook).
    pub fn dump(&self, trigger: FlightTrigger, detail: u64) -> Option<PathBuf> {
        let core = self.0.as_ref()?;
        if core.dumped.compare_exchange(false, true, Ordering::SeqCst, Ordering::SeqCst).is_err()
        {
            return None;
        }
        let records = core.ring.lock().unwrap().snapshot();
        let t_us = core.vnow.load(Ordering::Relaxed);
        let bytes = encode_dump(&core.key, core.seed, trigger, t_us, detail, &records);
        let write = || -> std::io::Result<()> {
            let mut f = std::fs::File::create(&core.sidecar)?;
            use std::io::Write;
            f.write_all(&bytes)?;
            f.sync_all()
        };
        match write() {
            Ok(()) => Some(core.sidecar.clone()),
            Err(e) => {
                eprintln!("flight: failed to write {}: {e}", core.sidecar.display());
                None
            }
        }
    }
}

impl std::fmt::Debug for FlightRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            None => write!(f, "FlightRecorder(off)"),
            Some(c) => write!(
                f,
                "FlightRecorder(armed, sidecar {}, dumped {})",
                c.sidecar.display(),
                c.dumped.load(Ordering::Relaxed)
            ),
        }
    }
}

/// Install a process-wide panic hook that dumps the black box with
/// [`FlightTrigger::Panic`] before chaining to the previous hook.
/// No-op for a disarmed recorder.  The dump latch makes the hook
/// idempotent and safe alongside other triggers.
pub fn install_panic_hook(rec: &FlightRecorder) {
    if !rec.is_enabled() {
        return;
    }
    let rec = rec.clone();
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        rec.dump(FlightTrigger::Panic, 0);
        prev(info);
    }));
}

/// Build the complete sealed dump byte stream (pure: same key, seed,
/// trigger, time, and records ⇒ identical bytes).
fn encode_dump(
    key: &SealKey,
    seed: u64,
    trigger: FlightTrigger,
    t_us: u64,
    detail: u64,
    records: &[FlightRecord],
) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(
        FILE_HDR_LEN + TRIGGER_LEN + records.len() * FlightRecord::WIRE_LEN + 1024,
    );
    bytes.extend_from_slice(&FLIGHT_MAGIC);
    bytes.extend_from_slice(&FLIGHT_VERSION.to_le_bytes());
    bytes.extend_from_slice(&0u32.to_le_bytes());
    bytes.extend_from_slice(&seed.to_le_bytes());

    let mut meta = [0u8; TRIGGER_LEN];
    meta[0] = trigger as u8;
    meta[8..16].copy_from_slice(&t_us.to_le_bytes());
    meta[16..24].copy_from_slice(&detail.to_le_bytes());
    meta[24..32].copy_from_slice(&(records.len() as u64).to_le_bytes());
    let tweak = |s, n| flight_tweak(seed, s, n);
    bytes.extend_from_slice(&frames::seal_frame(key, &FRAME_MAGIC, NONCE_DOMAIN, 0, &meta, tweak));

    for (i, batch) in records.chunks(BATCH).enumerate() {
        let mut payload = Vec::with_capacity(batch.len() * FlightRecord::WIRE_LEN);
        for r in batch {
            payload.extend_from_slice(&r.encode());
        }
        bytes.extend_from_slice(&frames::seal_frame(
            key,
            &FRAME_MAGIC,
            NONCE_DOMAIN,
            1 + i as u64,
            &payload,
            tweak,
        ));
    }
    bytes
}

/// A decoded black-box dump.
#[derive(Debug, Clone)]
pub struct FlightDump {
    pub seed: u64,
    pub trigger: FlightTrigger,
    /// Virtual time at which the trigger fired.
    pub trigger_t_us: u64,
    /// Trigger-specific detail word (e.g. shed-reason or alert code).
    pub detail: u64,
    /// Ring records, oldest first.
    pub records: Vec<FlightRecord>,
    /// True when the dump itself was torn (crash mid-dump): the decoded
    /// records are a valid prefix of what the ring held.
    pub truncated: bool,
}

/// Decode a sealed dump file.  Fails closed on tamper; a torn tail
/// yields `truncated: true` with the valid prefix.
pub fn decode_dump(path: &Path, key: &SealKey) -> Result<FlightDump> {
    let bytes =
        std::fs::read(path).with_context(|| format!("reading dump {}", path.display()))?;
    decode_dump_bytes(&bytes, key)
}

/// Decode a sealed dump from memory (see [`decode_dump`]).
pub fn decode_dump_bytes(bytes: &[u8], key: &SealKey) -> Result<FlightDump> {
    if bytes.len() < FILE_HDR_LEN {
        bail!("dump shorter than its {FILE_HDR_LEN}-byte header");
    }
    if bytes[..8] != FLIGHT_MAGIC {
        bail!("not a flight dump (bad magic)");
    }
    let version = u32::from_le_bytes(bytes[8..12].try_into().unwrap());
    if version != FLIGHT_VERSION {
        bail!("unsupported dump version {version}");
    }
    let seed = u64::from_le_bytes(bytes[16..24].try_into().unwrap());
    let (payloads, _valid) = frames::scan_frames(
        key,
        &FRAME_MAGIC,
        NONCE_DOMAIN,
        bytes,
        FILE_HDR_LEN,
        |s, n| flight_tweak(seed, s, n),
    )
    .map_err(|e| match e {
        frames::FrameError::Tamper(what) => {
            anyhow::anyhow!("tamper detected: {what} failed verification")
        }
        frames::FrameError::Corrupt(why) => anyhow::anyhow!("corrupt dump: {why}"),
    })?;
    let Some(meta) = payloads.first() else {
        bail!("dump has no trigger frame (torn before the first seal)");
    };
    if meta.len() != TRIGGER_LEN {
        bail!("trigger frame has {} bytes, expected {TRIGGER_LEN}", meta.len());
    }
    let trigger = FlightTrigger::from_code(meta[0])
        .ok_or_else(|| anyhow::anyhow!("unknown trigger code {}", meta[0]))?;
    let trigger_t_us = u64::from_le_bytes(meta[8..16].try_into().unwrap());
    let detail = u64::from_le_bytes(meta[16..24].try_into().unwrap());
    let stated = u64::from_le_bytes(meta[24..32].try_into().unwrap());
    let mut records = Vec::new();
    for p in &payloads[1..] {
        if p.len() % FlightRecord::WIRE_LEN != 0 {
            bail!("record batch of {} bytes is not a whole number of records", p.len());
        }
        for chunk in p.chunks(FlightRecord::WIRE_LEN) {
            records.push(FlightRecord::decode(chunk).unwrap());
        }
    }
    if records.len() as u64 > stated {
        bail!("dump holds {} records but claims {stated}", records.len());
    }
    let truncated = (records.len() as u64) < stated;
    Ok(FlightDump { seed, trigger, trigger_t_us, detail, records, truncated })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key() -> SealKey {
        SealKey::from_passphrase("flight-test-key")
    }

    fn dir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("champ-flight-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn fill(rec: &FlightRecorder, n: u64) {
        for i in 0..n {
            rec.set_vnow(i * 100);
            rec.span(TraceId::request(i), Stage::Compute, i * 100, i * 100 + 40, 1, 2);
            rec.event(TraceId::request(i), EventKind::Completed, i * 100 + 40, 1, 0);
            rec.sample(SeriesId::Goodput, i * 100, 42.5 + i as f64);
        }
    }

    #[test]
    fn disarmed_recorder_records_nothing_and_never_dumps() {
        let r = FlightRecorder::off();
        r.span(TraceId::request(1), Stage::Queue, 0, 10, 0, 0);
        r.event(TraceId::request(1), EventKind::Shed, 5, 0, 0);
        r.sample(SeriesId::P99, 5, 1.0);
        r.set_vnow(99);
        assert!(!r.is_enabled());
        assert_eq!(r.vnow(), 0);
        assert_eq!(r.dropped(), 0);
        assert!(!r.dumped());
        assert!(r.dump(FlightTrigger::Manual, 0).is_none());
        assert!(FlightRecorder::OFF.dump(FlightTrigger::Manual, 0).is_none());
    }

    #[test]
    fn ring_overwrites_oldest_and_counts_drops() {
        let d = dir("ring");
        let r = FlightRecorder::armed(7, key(), d.join("ring.bbx"));
        for i in 0..(RING_CAP as u64 + 10) {
            r.event(TraceId::request(i), EventKind::Offered, i, i, 0);
        }
        assert_eq!(r.dropped(), 10);
        let path = r.dump(FlightTrigger::Manual, 0).unwrap();
        let dump = decode_dump(&path, &key()).unwrap();
        assert_eq!(dump.records.len(), RING_CAP);
        // Oldest 10 gone, order chronological, newest survives.
        assert_eq!(dump.records.first().unwrap().t0_us, 10);
        assert_eq!(dump.records.last().unwrap().t0_us, RING_CAP as u64 + 9);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn dump_then_decode_roundtrips_all_record_families() {
        let d = dir("rt");
        let r = FlightRecorder::armed(42, key(), d.join("rt.bbx"));
        fill(&r, 300); // > BATCH records, so multiple batch frames
        r.set_vnow(29_900);
        let path = r.dump(FlightTrigger::JournalStalled, 3).unwrap();
        assert!(r.dumped());
        let dump = decode_dump(&path, &key()).unwrap();
        assert_eq!(dump.seed, 42);
        assert_eq!(dump.trigger, FlightTrigger::JournalStalled);
        assert_eq!(dump.trigger_t_us, 29_900);
        assert_eq!(dump.detail, 3);
        assert!(!dump.truncated);
        assert_eq!(dump.records.len(), 900);
        // Families decode to their typed views.
        let spans =
            dump.records.iter().filter(|r| {
                matches!(r.as_trace_record().map(|t| t.kind), Some(RecordKind::Span(_)))
            });
        assert_eq!(spans.count(), 300);
        let samples: Vec<_> = dump.records.iter().filter_map(|r| r.series()).collect();
        assert_eq!(samples.len(), 300);
        assert!(samples.iter().all(|s| *s == SeriesId::Goodput));
        let first_sample =
            dump.records.iter().find(|r| r.series().is_some()).unwrap();
        assert_eq!(f64::from_bits(first_sample.a), 42.5);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn dumps_are_byte_identical_for_same_seed_and_content() {
        let d = dir("det");
        let mk = |name: &str| {
            let r = FlightRecorder::armed(11, key(), d.join(name));
            fill(&r, 50);
            r.set_vnow(4_900);
            r.dump(FlightTrigger::ShedSpike, 1).unwrap()
        };
        let a = std::fs::read(mk("a.bbx")).unwrap();
        let b = std::fs::read(mk("b.bbx")).unwrap();
        assert_eq!(a, b, "same seed + same ring must dump identical bytes");
        // A different seed reseals under unrelated subkeys.
        let r = FlightRecorder::armed(12, key(), d.join("c.bbx"));
        fill(&r, 50);
        r.set_vnow(4_900);
        let c = std::fs::read(r.dump(FlightTrigger::ShedSpike, 1).unwrap()).unwrap();
        assert_ne!(a, c);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn first_trigger_wins_and_later_triggers_are_no_ops() {
        let d = dir("latch");
        let r = FlightRecorder::armed(5, key(), d.join("latch.bbx"));
        fill(&r, 10);
        assert!(r.dump(FlightTrigger::Eviction, 9).is_some());
        // More records + a second trigger must not rewrite the file.
        let before = std::fs::read(d.join("latch.bbx")).unwrap();
        fill(&r, 10);
        assert!(r.dump(FlightTrigger::Panic, 0).is_none());
        let after = std::fs::read(d.join("latch.bbx")).unwrap();
        assert_eq!(before, after);
        let dump = decode_dump(&d.join("latch.bbx"), &key()).unwrap();
        assert_eq!(dump.trigger, FlightTrigger::Eviction);
        assert_eq!(dump.detail, 9);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn tampered_dump_fails_closed_wrong_key_too() {
        let d = dir("tamper");
        let r = FlightRecorder::armed(3, key(), d.join("t.bbx"));
        fill(&r, 20);
        let path = r.dump(FlightTrigger::Manual, 0).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Sampled interior bit flips (every 7th byte keeps the test fast).
        for i in (FILE_HDR_LEN..bytes.len()).step_by(7) {
            let mut bad = bytes.clone();
            bad[i] ^= 1;
            assert!(decode_dump_bytes(&bad, &key()).is_err(), "byte {i}: flip accepted");
        }
        let wrong = SealKey::from_passphrase("not-the-key");
        assert!(decode_dump_bytes(&bytes, &wrong).is_err());
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn torn_dump_decodes_to_a_truncated_prefix() {
        let d = dir("torn");
        let r = FlightRecorder::armed(8, key(), d.join("torn.bbx"));
        fill(&r, 300); // two batch frames
        let path = r.dump(FlightTrigger::Panic, 0).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // Cut mid-way through the last frame: the crash being recorded
        // can tear the dump itself.
        let cut = bytes.len() - 100;
        let dump = decode_dump_bytes(&bytes[..cut], &key()).unwrap();
        assert!(dump.truncated, "short tail must surface as truncation");
        assert!(dump.records.len() < 900);
        assert!(!dump.records.is_empty());
        assert_eq!(dump.trigger, FlightTrigger::Panic);
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn panic_hook_dumps_then_chains() {
        let d = dir("panic");
        let r = FlightRecorder::armed(2, key(), d.join("panic.bbx"));
        fill(&r, 5);
        r.set_vnow(400);
        install_panic_hook(&r);
        let caught = std::panic::catch_unwind(|| panic!("boom"));
        // Restore the default hook so later tests print panics normally.
        let _ = std::panic::take_hook();
        assert!(caught.is_err());
        let dump = decode_dump(&d.join("panic.bbx"), &key()).unwrap();
        assert_eq!(dump.trigger, FlightTrigger::Panic);
        assert_eq!(dump.trigger_t_us, 400);
        assert_eq!(dump.records.len(), 15);
        std::fs::remove_dir_all(&d).ok();
    }
}
