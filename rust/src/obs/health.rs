//! The end-of-run "SLO health" text surface.
//!
//! Operators at a checkpoint get one screen, not a JSON artifact: which
//! class or tenant is burning its error budget, and where the slow
//! requests actually spent their time.  [`health_summary`] renders both
//! from a [`TraceSnapshot`] plus budget rows the serve layer supplies.
//!
//! The module defines its own [`BudgetRow`] rather than importing serve
//! types: obs sits below serve in the layer order, and the health surface
//! should render anything that can express offered/completed/shed.

use super::recorder::{RecordKind, Stage, TraceId, TraceRecord};
use super::TraceSnapshot;

/// One error-budget line: a class or tenant's terminal accounting.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct BudgetRow {
    /// "class" or "tenant".
    pub scope: &'static str,
    pub name: String,
    pub offered: u64,
    pub completed: u64,
    pub shed: u64,
    /// Completions that landed past their deadline.
    pub deadline_misses: u64,
    pub p99_us: u64,
}

impl BudgetRow {
    /// Fraction of offered requests that missed their SLO (shed or late).
    /// "Budget burn": 0.0 = untouched budget, 1.0 = nothing on time.
    pub fn burn(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        (self.shed + self.deadline_misses) as f64 / self.offered as f64
    }
}

fn trace_label(t: TraceId) -> String {
    if t == TraceId::STORAGE {
        "storage".to_string()
    } else if t.is_frame() {
        format!("frame#{}", t.0 & 0x00FF_FFFF_FFFF_FFFF)
    } else {
        format!("req#{}", t.0)
    }
}

/// The top `n` widest spans of `stage`, slowest first; ties broken by the
/// record sort key so the listing is deterministic.
pub fn slowest_spans(records: &[TraceRecord], stage: Stage, n: usize) -> Vec<TraceRecord> {
    let mut spans: Vec<TraceRecord> = records
        .iter()
        .filter(|r| matches!(r.kind, RecordKind::Span(s) if s == stage))
        .copied()
        .collect();
    spans.sort_unstable_by(|a, b| {
        b.dur_us().cmp(&a.dur_us()).then_with(|| a.sort_key().cmp(&b.sort_key()))
    });
    spans.truncate(n);
    spans
}

/// Render the SLO health text: budget-burn rows, then the top-5 slowest
/// spans for each stage that appears in the trace.
pub fn health_summary(snap: &TraceSnapshot, rows: &[BudgetRow]) -> String {
    let mut out = String::new();
    out.push_str("SLO health\n");
    out.push_str("  scope   name          offered  completed  shed  late   burn    p99_us\n");
    for r in rows {
        out.push_str(&format!(
            "  {:<7} {:<13} {:>7} {:>10} {:>5} {:>5}  {:>5.1}% {:>9}\n",
            r.scope,
            r.name,
            r.offered,
            r.completed,
            r.shed,
            r.deadline_misses,
            r.burn() * 100.0,
            r.p99_us,
        ));
    }
    out.push_str("  slowest spans by stage (top 5)\n");
    for stage in Stage::ALL {
        let top = slowest_spans(&snap.records, stage, 5);
        if top.is_empty() {
            continue;
        }
        out.push_str(&format!("    {}:\n", stage.as_str()));
        for r in top {
            out.push_str(&format!(
                "      {:<12} {:>9}us  [{} .. {}]\n",
                trace_label(r.trace),
                r.dur_us(),
                r.t0_us,
                r.t1_us,
            ));
        }
    }
    if snap.dropped > 0 {
        out.push_str(&format!(
            "  warning: {} records lost to ring overflow — trace is partial\n",
            snap.dropped
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::recorder::TraceRecorder;

    #[test]
    fn burn_math() {
        let r = BudgetRow {
            scope: "class",
            name: "Identify".into(),
            offered: 100,
            completed: 90,
            shed: 10,
            deadline_misses: 5,
            p99_us: 4_000,
        };
        assert!((r.burn() - 0.15).abs() < 1e-12);
        assert_eq!(BudgetRow::default().burn(), 0.0);
    }

    #[test]
    fn slowest_spans_rank_by_duration_deterministically() {
        let rec = TraceRecorder::enabled();
        for (id, d) in [(1u64, 50u64), (2, 300), (3, 100), (4, 300), (5, 10), (6, 80), (7, 90)] {
            rec.span(TraceId::request(id), Stage::Compute, 0, d, 0, 0);
        }
        rec.span(TraceId::request(9), Stage::Queue, 0, 999, 0, 0);
        let records = rec.snapshot();
        let top = slowest_spans(&records, Stage::Compute, 5);
        assert_eq!(top.len(), 5);
        let durs: Vec<u64> = top.iter().map(TraceRecord::dur_us).collect();
        assert_eq!(durs, vec![300, 300, 100, 90, 80]);
        // Duration tie between req#2 and req#4 resolves by sort key.
        assert_eq!(top[0].trace, TraceId::request(2));
        assert_eq!(top[1].trace, TraceId::request(4));
    }

    #[test]
    fn summary_renders_rows_and_stages() {
        let rec = TraceRecorder::enabled();
        rec.span(TraceId::request(1), Stage::Queue, 0, 120, 0, 0);
        rec.span(TraceId::request(1), Stage::Compute, 120, 500, 0, 0);
        let snap = TraceSnapshot { records: rec.snapshot(), ..Default::default() };
        let rows = vec![BudgetRow {
            scope: "tenant",
            name: "border-patrol".into(),
            offered: 40,
            completed: 38,
            shed: 2,
            deadline_misses: 0,
            p99_us: 3_200,
        }];
        let text = health_summary(&snap, &rows);
        assert!(text.contains("SLO health"));
        assert!(text.contains("border-patrol"));
        assert!(text.contains("5.0%"));
        assert!(text.contains("queue:"));
        assert!(text.contains("compute:"));
        assert!(text.contains("req#1"));
        assert!(!text.contains("warning"), "no drops => no warning line");
    }

    #[test]
    fn dropped_records_warn() {
        let snap = TraceSnapshot { dropped: 7, ..Default::default() };
        let text = health_summary(&snap, &[]);
        assert!(text.contains("7 records lost"));
    }
}
