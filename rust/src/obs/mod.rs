//! Observability: end-to-end causal tracing + the metrics registry.
//!
//! The serving story so far was post-hoc: `BENCH_*.json` says *that* a
//! p99 blew its deadline, never *where* the time went.  This module is
//! the cross-layer spine that answers the second question:
//!
//! * [`recorder::TraceRecorder`] — a sharded, lock-light ring buffer of
//!   typed span/event records stamped with **virtual time** and a
//!   per-request [`recorder::TraceId`].  The id is born at
//!   `serve::admission` intake and flows through EDF queue residency,
//!   batch dispatch, bus-grant waits, cartridge compute, and the vdisk
//!   unseal waves under a mount — one connected chain per request whose
//!   span durations tile arrival → completion exactly.
//! * [`registry::MetricsRegistry`] — named counters / gauges /
//!   log-bucketed histograms the serve, engine, and vdisk layers publish
//!   into (queue depth, credit occupancy, shard hit rate, shed-by-reason),
//!   one place the reports read instead of ad-hoc tallies.
//! * [`export`] — Chrome/Perfetto trace-event JSON and folded-stacks
//!   flamegraph text, both emitted through the crate's own `json` module.
//! * [`health`] — the end-of-run "SLO health" text surface: per-class and
//!   per-tenant budget burn plus the top-5 slowest spans by stage.
//! * [`flight`] — the black-box flight recorder: a small always-on ring
//!   of recent spans/events/metric samples, sealed and dumped to a
//!   sidecar `.bbx` file automatically when something goes wrong
//!   (shed spike, miss burst, eviction, journal stall, panic).
//! * [`detect`] — streaming EWMA z-score detectors and multi-window SLO
//!   burn-rate alerting over the per-tick series, deterministic in
//!   virtual time; its level output drives the closed-loop admission
//!   governor in `serve::admission`.
//!
//! Two invariants the rest of the crate leans on:
//!
//! 1. **Zero-cost when disabled.**  [`TraceRecorder::off`] is the `None`
//!    niche of an `Option<Arc<_>>`; every record method is an `#[inline]`
//!    early return the optimizer folds away, and the disabled path records
//!    exactly zero events (property-tested in `tests/obs_effect.rs`).
//! 2. **Deterministic when enabled.**  Records carry only virtual-time
//!    stamps and values already flowing through the call sites — no wall
//!    clock, no RNG, no `HashMap` iteration order.  Snapshots sort by a
//!    total key, so the same seed yields a bit-identical trace, and a
//!    traced run's reports are bit-identical to an untraced run's.

pub mod detect;
pub mod export;
pub mod flight;
pub mod health;
pub mod recorder;
pub mod registry;

pub use detect::{AlertKind, AlertScope, AnomalyAlert, AnomalyEngine, SeriesId, SloBudget};
pub use flight::{FlightDump, FlightRecord, FlightRecorder, FlightTrigger};
pub use recorder::{EventKind, RecordKind, Stage, TraceId, TraceRecord, TraceRecorder};
pub use registry::{HistSummary, MetricsRegistry, MetricsSnapshot};

/// Everything a traced run hands its caller: the sorted record stream
/// plus the registry snapshot taken at the same instant.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceSnapshot {
    pub records: Vec<TraceRecord>,
    pub metrics: MetricsSnapshot,
    /// Records lost to ring overflow (0 in every bundled workload).
    pub dropped: u64,
}
