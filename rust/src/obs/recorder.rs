//! The trace recorder: typed records in sharded ring buffers.
//!
//! A record is 48 bytes of plain data — no strings, no allocation on the
//! hot path.  Shards are keyed by trace id, so concurrent writers (the
//! vdisk unseal walk vs. the virtual-time event loop) rarely share a
//! lock, and each shard is a fixed ring that overwrites its oldest entry
//! rather than growing: tracing can never turn a serving run into an OOM.
//!
//! [`TraceRecorder`] is a newtype over `Option<Arc<Core>>`.  The disabled
//! recorder is the `None` niche ([`TraceRecorder::off`], also available as
//! the `const` [`TraceRecorder::OFF`]): every method is an `#[inline]`
//! early return, so a build that never enables tracing pays a dead branch
//! the optimizer removes — the compile-time no-op path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Shards of the record buffer (writers hash by trace id).
const SHARDS: usize = 8;

/// Records retained per shard before the ring overwrites its oldest.
const RING_CAP: usize = 1 << 15;

/// The causal identity a record belongs to.
///
/// The id space is partitioned so the three record families never
/// collide: serving requests keep their request id, engine device-frames
/// are offset into a high band, and storage-side records share one
/// sentinel id (they attach to the media, not to a request).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceId(pub u64);

impl TraceId {
    /// Storage-track records (mount, unseal waves, cache sweeps).
    pub const STORAGE: TraceId = TraceId(u64::MAX);

    /// A serving-layer request, identified by its request id.
    pub fn request(id: u64) -> TraceId {
        TraceId(id)
    }

    /// An engine device-frame, identified by its batch head sequence.
    pub fn frame(seq: u64) -> TraceId {
        TraceId(0x0100_0000_0000_0000 | seq)
    }

    /// True for ids minted by [`TraceId::frame`].
    pub fn is_frame(&self) -> bool {
        *self != TraceId::STORAGE && self.0 & 0x0100_0000_0000_0000 != 0
    }
}

/// Span stages, in causal order along a request's journey.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Stage {
    /// Admission decision (token bucket + queue bound), zero-width.
    Admission = 0,
    /// EDF queue residency: admit → pop.
    Queue = 1,
    /// Batch formation at pop time, zero-width.
    Dispatch = 2,
    /// Waiting for the granted resource (shared wire / match server /
    /// stage timeline) to come free: pop → service start.
    BusGrant = 3,
    /// Service on the granted resource: start → completion.
    Compute = 4,
    /// A transfer occupying the shared wire or a peer link.
    Wire = 5,
    /// Host-side submission preparation (engine dispatch).
    HostPrep = 6,
    /// One bounded wave of the vdisk parallel unseal walk.
    UnsealWave = 7,
    /// Federation fan-out: the router splitting a probe batch into per-unit
    /// sub-queries, zero-width per request.
    Scatter = 8,
    /// Waiting for the slowest probed unit in a scatter-gather pass:
    /// fan-out → last per-unit answer.
    ProbeWait = 9,
    /// Deterministic bounded heap-merge of per-unit top-k lists.
    Merge = 10,
}

impl Stage {
    pub fn as_str(&self) -> &'static str {
        match self {
            Stage::Admission => "admission",
            Stage::Queue => "queue",
            Stage::Dispatch => "dispatch",
            Stage::BusGrant => "bus-grant",
            Stage::Compute => "compute",
            Stage::Wire => "wire",
            Stage::HostPrep => "host-prep",
            Stage::UnsealWave => "unseal-wave",
            Stage::Scatter => "scatter",
            Stage::ProbeWait => "probe-wait",
            Stage::Merge => "merge",
        }
    }

    pub const ALL: [Stage; 11] = [
        Stage::Admission,
        Stage::Queue,
        Stage::Dispatch,
        Stage::BusGrant,
        Stage::Compute,
        Stage::Wire,
        Stage::HostPrep,
        Stage::UnsealWave,
        Stage::Scatter,
        Stage::ProbeWait,
        Stage::Merge,
    ];

    /// Inverse of the span discriminant (for flight-ring decode).
    pub fn from_code(c: u8) -> Option<Stage> {
        Stage::ALL.get(c as usize).copied()
    }
}

/// Instantaneous (zero-width) trace events.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum EventKind {
    /// A request was offered to admission (`a` = class, `b` = tenant).
    Offered = 0,
    /// A request was shed (`a` = shed-reason code, `b` = class).
    Shed = 1,
    /// A request reached its terminal completion (`a` = on-time as 0/1).
    Completed = 2,
    /// Evicted in-flight work went back into its class queue.
    Requeued = 3,
    /// The wire arbiter postponed granting: an earlier event may add a
    /// competing transfer (`a` = pending transfers at the decision).
    BusDefer = 4,
    /// Sealed media mounted (`a` = media uid).
    MediaMount = 5,
    /// Sealed media unmounted (`a` = media uid).
    MediaUnmount = 6,
    /// Background journal compaction folded the sidecar into a fresh
    /// image (`a` = frames folded, `b` = new image uid truncated to u64).
    MediaCompaction = 7,
    /// A streaming detector or burn-rate alerter fired (`a` = packed
    /// alert code, `b` = observed value as `f64::to_bits`).
    Alert = 8,
    /// The flight recorder dumped its black box (`a` = trigger code,
    /// `b` = trigger detail word).
    FlightDump = 9,
}

impl EventKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            EventKind::Offered => "offered",
            EventKind::Shed => "shed",
            EventKind::Completed => "completed",
            EventKind::Requeued => "requeued",
            EventKind::BusDefer => "bus-defer",
            EventKind::MediaMount => "media-mount",
            EventKind::MediaUnmount => "media-unmount",
            EventKind::MediaCompaction => "media-compaction",
            EventKind::Alert => "alert",
            EventKind::FlightDump => "flight-dump",
        }
    }

    /// Inverse of the event discriminant (for flight-ring decode).
    pub fn from_code(c: u8) -> Option<EventKind> {
        Some(match c {
            0 => EventKind::Offered,
            1 => EventKind::Shed,
            2 => EventKind::Completed,
            3 => EventKind::Requeued,
            4 => EventKind::BusDefer,
            5 => EventKind::MediaMount,
            6 => EventKind::MediaUnmount,
            7 => EventKind::MediaCompaction,
            8 => EventKind::Alert,
            9 => EventKind::FlightDump,
            _ => return None,
        })
    }
}

/// Span or instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum RecordKind {
    Span(Stage),
    Event(EventKind),
}

impl RecordKind {
    /// Total order over record kinds (spans sort before events at equal
    /// timestamps, each family by its discriminant).  This is also the
    /// flight-ring wire code: spans in `0x00..=0x3F`, events in
    /// `0x40..=0x7F` (the `0x80` bit is reserved for metric samples,
    /// which exist only in the flight ring).
    pub(crate) fn code(&self) -> u8 {
        match self {
            RecordKind::Span(s) => *s as u8,
            RecordKind::Event(e) => 0x40 | *e as u8,
        }
    }

    /// Inverse of [`RecordKind::code`] over the span/event bands.
    pub(crate) fn from_code(c: u8) -> Option<RecordKind> {
        if c & 0x80 != 0 {
            return None; // metric-sample band: not a trace record kind
        }
        if c & 0x40 == 0 {
            Stage::from_code(c).map(RecordKind::Span)
        } else {
            EventKind::from_code(c & !0x40).map(RecordKind::Event)
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            RecordKind::Span(s) => s.as_str(),
            RecordKind::Event(e) => e.as_str(),
        }
    }
}

/// One trace record.  `t0_us == t1_us` for instants; `a`/`b` are
/// kind-specific payload words (documented on [`Stage`]/[`EventKind`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceRecord {
    pub trace: TraceId,
    pub kind: RecordKind,
    pub t0_us: u64,
    pub t1_us: u64,
    pub a: u64,
    pub b: u64,
}

impl TraceRecord {
    pub fn dur_us(&self) -> u64 {
        self.t1_us.saturating_sub(self.t0_us)
    }

    /// Total sort key: time, then causal id, then kind, then payload —
    /// independent of shard placement or writer interleaving, so a
    /// snapshot is bit-identical across same-seed runs.
    pub fn sort_key(&self) -> (u64, u64, u8, u64, u64, u64) {
        (self.t0_us, self.trace.0, self.kind.code(), self.t1_us, self.a, self.b)
    }
}

/// Fixed-capacity overwrite ring.
struct Ring {
    buf: Vec<TraceRecord>,
    /// Next write position once the ring has wrapped.
    head: usize,
    wrapped: bool,
}

impl Ring {
    fn new() -> Self {
        Ring { buf: Vec::new(), head: 0, wrapped: false }
    }

    fn push(&mut self, r: TraceRecord) -> bool {
        if self.wrapped {
            self.buf[self.head] = r;
            self.head = (self.head + 1) % RING_CAP;
            return true;
        }
        self.buf.push(r);
        if self.buf.len() == RING_CAP {
            self.wrapped = true;
        }
        false
    }
}

struct Core {
    shards: Vec<Mutex<Ring>>,
    /// Virtual "now" for writers that have no clock of their own (the
    /// vdisk unseal walk runs on OS threads; the event loop publishes its
    /// virtual time here before calling into storage).
    vnow: AtomicU64,
    dropped: AtomicU64,
}

/// The recorder handle: cheap to clone, `off()` is free to call into.
#[derive(Clone, Default)]
pub struct TraceRecorder(Option<Arc<Core>>);

impl TraceRecorder {
    /// The disabled recorder as a `const` (compile-time no-op path).
    pub const OFF: TraceRecorder = TraceRecorder(None);

    /// A recorder that records nothing and allocates nothing.
    pub fn off() -> Self {
        TraceRecorder(None)
    }

    /// A live recorder with empty rings.
    pub fn enabled() -> Self {
        TraceRecorder(Some(Arc::new(Core {
            shards: (0..SHARDS).map(|_| Mutex::new(Ring::new())).collect(),
            vnow: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
        })))
    }

    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Publish the event loop's virtual time for clock-less writers.
    #[inline]
    pub fn set_vnow(&self, t_us: u64) {
        if let Some(core) = &self.0 {
            core.vnow.store(t_us, Ordering::Relaxed);
        }
    }

    /// Last published virtual time (0 when disabled).
    #[inline]
    pub fn vnow(&self) -> u64 {
        self.0.as_ref().map(|c| c.vnow.load(Ordering::Relaxed)).unwrap_or(0)
    }

    #[inline]
    fn push(&self, r: TraceRecord) {
        let Some(core) = &self.0 else { return };
        let shard = (r.trace.0 as usize).wrapping_mul(0x9E37_79B9) % SHARDS;
        let overwrote = core.shards[shard].lock().unwrap().push(r);
        if overwrote {
            core.dropped.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record a completed span `[t0, t1]`.
    #[inline]
    pub fn span(&self, trace: TraceId, stage: Stage, t0_us: u64, t1_us: u64, a: u64, b: u64) {
        if self.0.is_none() {
            return;
        }
        self.push(TraceRecord { trace, kind: RecordKind::Span(stage), t0_us, t1_us, a, b });
    }

    /// Record an instant event at `t`.
    #[inline]
    pub fn event(&self, trace: TraceId, kind: EventKind, t_us: u64, a: u64, b: u64) {
        if self.0.is_none() {
            return;
        }
        self.push(TraceRecord {
            trace,
            kind: RecordKind::Event(kind),
            t0_us: t_us,
            t1_us: t_us,
            a,
            b,
        });
    }

    /// Records overwritten by ring overflow since creation.
    pub fn dropped(&self) -> u64 {
        self.0.as_ref().map(|c| c.dropped.load(Ordering::Relaxed)).unwrap_or(0)
    }

    /// A deterministic copy of every retained record, sorted by
    /// [`TraceRecord::sort_key`].  Empty when disabled.
    pub fn snapshot(&self) -> Vec<TraceRecord> {
        let Some(core) = &self.0 else { return Vec::new() };
        let mut out = Vec::new();
        for shard in &core.shards {
            let ring = shard.lock().unwrap();
            out.extend_from_slice(&ring.buf);
        }
        out.sort_unstable_by_key(|r| r.sort_key());
        out
    }
}

impl std::fmt::Debug for TraceRecorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            None => write!(f, "TraceRecorder(off)"),
            Some(_) => write!(f, "TraceRecorder(on, {} records)", self.snapshot().len()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_recorder_records_nothing() {
        let r = TraceRecorder::off();
        r.span(TraceId::request(1), Stage::Queue, 0, 10, 0, 0);
        r.event(TraceId::request(1), EventKind::Offered, 0, 0, 0);
        r.set_vnow(99);
        assert!(!r.is_enabled());
        assert!(r.snapshot().is_empty());
        assert_eq!(r.vnow(), 0);
        assert_eq!(r.dropped(), 0);
        assert!(TraceRecorder::OFF.snapshot().is_empty());
    }

    #[test]
    fn snapshot_is_sorted_regardless_of_insert_order() {
        let r = TraceRecorder::enabled();
        r.span(TraceId::request(9), Stage::Compute, 50, 80, 0, 0);
        r.event(TraceId::request(2), EventKind::Offered, 10, 0, 0);
        r.span(TraceId::request(2), Stage::Queue, 10, 40, 0, 0);
        r.span(TraceId::request(1), Stage::Queue, 10, 30, 0, 0);
        let snap = r.snapshot();
        assert_eq!(snap.len(), 4);
        let keys: Vec<_> = snap.iter().map(TraceRecord::sort_key).collect();
        let mut sorted = keys.clone();
        sorted.sort_unstable();
        assert_eq!(keys, sorted);
        // Span sorts before the event at the same (t, trace).
        assert_eq!(snap[0].trace, TraceId::request(1));
        assert!(matches!(snap[1].kind, RecordKind::Span(Stage::Queue)));
        assert!(matches!(snap[2].kind, RecordKind::Event(EventKind::Offered)));
    }

    #[test]
    fn clones_share_the_buffer() {
        let r = TraceRecorder::enabled();
        let c = r.clone();
        c.span(TraceId::request(1), Stage::Admission, 5, 5, 0, 0);
        c.set_vnow(42);
        assert_eq!(r.snapshot().len(), 1);
        assert_eq!(r.vnow(), 42);
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let r = TraceRecorder::enabled();
        // All on one trace id => one shard; overflow it.
        let n = (RING_CAP + 10) as u64;
        for i in 0..n {
            r.span(TraceId::request(8), Stage::Compute, i, i + 1, 0, 0);
        }
        assert_eq!(r.dropped(), 10);
        let snap = r.snapshot();
        assert_eq!(snap.len(), RING_CAP);
        // The oldest 10 records are gone; the newest survive.
        assert_eq!(snap.first().unwrap().t0_us, 10);
        assert_eq!(snap.last().unwrap().t0_us, n - 1);
    }

    #[test]
    fn trace_id_bands_do_not_collide() {
        assert_ne!(TraceId::request(5), TraceId::frame(5));
        assert!(TraceId::frame(5).is_frame());
        assert!(!TraceId::request(5).is_frame());
        assert!(!TraceId::STORAGE.is_frame());
    }

    #[test]
    fn record_kind_codes_roundtrip() {
        for s in Stage::ALL {
            let k = RecordKind::Span(s);
            assert_eq!(RecordKind::from_code(k.code()), Some(k));
        }
        for c in 0u8..16 {
            let Some(e) = EventKind::from_code(c) else { break };
            let k = RecordKind::Event(e);
            assert_eq!(k.code(), 0x40 | c);
            assert_eq!(RecordKind::from_code(k.code()), Some(k));
        }
        // The metric-sample band and out-of-range codes decode to None.
        assert_eq!(RecordKind::from_code(0x80), None);
        assert_eq!(RecordKind::from_code(0x3F), None);
        assert_eq!(RecordKind::from_code(0x7F), None);
    }

    #[test]
    fn vnow_is_shared_with_storage_side_writers() {
        let r = TraceRecorder::enabled();
        r.set_vnow(1_000);
        let t = r.vnow();
        r.span(TraceId::STORAGE, Stage::UnsealWave, t, t, 4, 2);
        let snap = r.snapshot();
        assert_eq!(snap[0].t0_us, 1_000);
        assert_eq!(snap[0].trace, TraceId::STORAGE);
    }
}
