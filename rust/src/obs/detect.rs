//! Streaming anomaly detection and SLO burn-rate alerting in virtual time.
//!
//! The serve session feeds one [`TickSample`] per health tick (100 ms of
//! virtual time): per-class and per-tenant deltas of "bad" terminal
//! outcomes, plus instantaneous values of the global series it already
//! tracks (goodput, p99, shed rate, cache hit rate, bus defer rate).  The
//! [`AnomalyEngine`] runs two detector families over those feeds:
//!
//! * **EWMA z-score spike detectors** ([`ZScore`]) over each global
//!   series — a cheap change-point test that flags a sample more than
//!   `threshold` deviations from the exponentially-weighted mean.  The
//!   mean/variance update *after* the test, so a genuine step change is
//!   seen before the baseline absorbs it.
//! * **Multi-window SLO burn-rate alerts** ([`BurnScope`]) per class and
//!   per tenant.  The SLO budget is a bad-outcome fraction
//!   ([`SloBudget::DEFAULT_BAD_BUDGET`]); a window's *burn rate* is the
//!   observed bad fraction over that window divided by the budget.  An
//!   alert fires only when both a long window and its short confirmation
//!   window exceed the factor — the long window gives significance, the
//!   short one makes the alert reset quickly once the burn stops
//!   (multi-window burn alerting per Google SRE workbook ch. 5, scaled
//!   to virtual-time ticks).
//!
//! "Bad" deliberately **excludes rate-limited sheds**: those are the
//! admission governor's own action, and counting them as burn would lock
//! the control loop at its floor (shed → burn → scale down → more shed).
//! Deadline misses and the post-admission shed reasons (queue-full,
//! expired, evicted, journal-stalled) count.
//!
//! Everything here is pure arithmetic over caller-supplied virtual-time
//! samples — no wall clock, no RNG, iteration in index order — so the
//! alert stream is bit-identical across same-seed runs.

/// Virtual-time tick width the engine is calibrated for (matches the
/// serve session's health tick).
pub const TICK_US: u64 = 100_000;

/// The global metric series the spike detectors watch.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum SeriesId {
    /// On-time completions per tick.
    Goodput = 0,
    /// p99 of terminal latencies observed this tick (µs).
    P99 = 1,
    /// Sheds per offered request this tick.
    ShedRate = 2,
    /// Block-cache hit fraction this tick.
    CacheHitRate = 3,
    /// Wire-arbiter defers per dispatch this tick.
    BusDeferRate = 4,
}

impl SeriesId {
    pub const ALL: [SeriesId; 5] = [
        SeriesId::Goodput,
        SeriesId::P99,
        SeriesId::ShedRate,
        SeriesId::CacheHitRate,
        SeriesId::BusDeferRate,
    ];

    pub fn as_str(&self) -> &'static str {
        match self {
            SeriesId::Goodput => "goodput",
            SeriesId::P99 => "p99",
            SeriesId::ShedRate => "shed-rate",
            SeriesId::CacheHitRate => "cache-hit-rate",
            SeriesId::BusDeferRate => "bus-defer-rate",
        }
    }

    /// Inverse of the discriminant (for flight-ring decode).
    pub fn from_code(c: u8) -> Option<SeriesId> {
        SeriesId::ALL.get(c as usize).copied()
    }
}

/// Exponentially-weighted mean/variance z-score detector.
///
/// `observe` tests the incoming sample against the *current* baseline and
/// only then folds it in, so a step change scores against the pre-step
/// mean.  A relative floor on the standard deviation keeps a flat series
/// from turning numerical dust into infinite z-scores.
#[derive(Debug, Clone)]
pub struct ZScore {
    mean: f64,
    var: f64,
    alpha: f64,
    threshold: f64,
    warmup: u32,
    seen: u32,
}

impl ZScore {
    pub fn new(alpha: f64, threshold: f64, warmup: u32) -> Self {
        ZScore { mean: 0.0, var: 0.0, alpha, threshold, warmup, seen: 0 }
    }

    /// Feed one sample; returns `Some(z)` when the sample is anomalous
    /// (past warmup and `|z| > threshold`).
    pub fn observe(&mut self, x: f64) -> Option<f64> {
        let fired = if self.seen >= self.warmup {
            let std = self.var.sqrt().max(1e-9 + 0.05 * self.mean.abs());
            let z = (x - self.mean) / std;
            (z.abs() > self.threshold).then_some(z)
        } else {
            None
        };
        if self.seen == 0 {
            self.mean = x;
        } else {
            let d = x - self.mean;
            self.mean += self.alpha * d;
            self.var = (1.0 - self.alpha) * (self.var + self.alpha * d * d);
        }
        self.seen = self.seen.saturating_add(1);
        fired
    }
}

/// One burn-rate window pair: a long window for significance and a short
/// confirmation window for fast reset.
#[derive(Debug, Clone, Copy)]
pub struct WindowSpec {
    /// Long-window length in ticks.
    pub long: usize,
    /// Short confirmation-window length in ticks.
    pub short: usize,
    /// Burn-rate factor both windows must exceed.
    pub factor: f64,
    pub label: &'static str,
}

/// The two window pairs every scope is evaluated against.
pub const BURN_WINDOWS: [WindowSpec; 2] = [
    WindowSpec { long: 25, short: 5, factor: 8.0, label: "fast" },
    WindowSpec { long: 100, short: 25, factor: 2.0, label: "slow" },
];

/// SLO error budget: the tolerated fraction of bad terminal outcomes.
#[derive(Debug, Clone, Copy)]
pub struct SloBudget(pub f64);

impl SloBudget {
    pub const DEFAULT_BAD_BUDGET: f64 = 0.1;
}

impl Default for SloBudget {
    fn default() -> Self {
        SloBudget(Self::DEFAULT_BAD_BUDGET)
    }
}

/// Per-scope (class or tenant) burn-rate state: a ring of per-tick
/// `(bad, total)` deltas plus the firing edge per window pair.
#[derive(Debug, Clone)]
pub struct BurnScope {
    ring: std::collections::VecDeque<(u64, u64)>,
    firing: [bool; BURN_WINDOWS.len()],
}

impl BurnScope {
    pub fn new() -> Self {
        BurnScope {
            ring: std::collections::VecDeque::with_capacity(BURN_WINDOWS[1].long),
            firing: [false; BURN_WINDOWS.len()],
        }
    }

    fn window_burn(&self, len: usize, budget: f64) -> f64 {
        let take = len.min(self.ring.len());
        let (mut bad, mut total) = (0u64, 0u64);
        for &(b, t) in self.ring.iter().rev().take(take) {
            bad += b;
            total += t;
        }
        if total == 0 {
            return 0.0;
        }
        (bad as f64 / total as f64) / budget
    }

    /// Push one tick's delta; returns `(newly_fired, burning)` where
    /// `newly_fired` holds `(window_index, long_window_burn)` for each
    /// pair that transitioned into the firing state this tick, and
    /// `burning` is true while *any* pair's condition holds (level
    /// signal for the admission governor).
    pub fn push(&mut self, bad: u64, total: u64, budget: f64) -> (Vec<(usize, f64)>, bool) {
        self.ring.push_back((bad, total));
        while self.ring.len() > BURN_WINDOWS[BURN_WINDOWS.len() - 1].long {
            self.ring.pop_front();
        }
        let mut fired = Vec::new();
        let mut burning = false;
        for (i, w) in BURN_WINDOWS.iter().enumerate() {
            let long = self.window_burn(w.long, budget);
            let short = self.window_burn(w.short, budget);
            let hot = long > w.factor && short > w.factor;
            if hot && !self.firing[i] {
                fired.push((i, long));
            }
            self.firing[i] = hot;
            burning |= hot;
        }
        (fired, burning)
    }
}

impl Default for BurnScope {
    fn default() -> Self {
        Self::new()
    }
}

/// What fired.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum AlertKind {
    /// Fast burn-rate pair (8× over 2.5 s confirmed over 0.5 s).
    BurnFast = 0,
    /// Slow burn-rate pair (2× over 10 s confirmed over 2.5 s).
    BurnSlow = 1,
    /// A z-score spike on a global series.
    Spike = 2,
}

impl AlertKind {
    pub fn as_str(&self) -> &'static str {
        match self {
            AlertKind::BurnFast => "burn-fast",
            AlertKind::BurnSlow => "burn-slow",
            AlertKind::Spike => "spike",
        }
    }

    pub fn from_code(c: u8) -> Option<AlertKind> {
        Some(match c {
            0 => AlertKind::BurnFast,
            1 => AlertKind::BurnSlow,
            2 => AlertKind::Spike,
            _ => return None,
        })
    }
}

/// Whose budget (or series) the alert concerns.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlertScope {
    Global,
    Class(u8),
    Tenant(u8),
}

impl AlertScope {
    fn code(&self) -> (u8, u8) {
        match self {
            AlertScope::Global => (0, 0),
            AlertScope::Class(i) => (1, *i),
            AlertScope::Tenant(i) => (2, *i),
        }
    }

    fn from_code(kind: u8, idx: u8) -> Option<AlertScope> {
        Some(match kind {
            0 => AlertScope::Global,
            1 => AlertScope::Class(idx),
            2 => AlertScope::Tenant(idx),
            _ => return None,
        })
    }
}

/// One typed anomaly alert, edge-triggered and deterministic per seed.
#[derive(Debug, Clone, PartialEq)]
pub struct AnomalyAlert {
    /// Virtual time of the tick that fired.
    pub t_us: u64,
    pub kind: AlertKind,
    pub scope: AlertScope,
    /// The series a spike fired on; `None` for burn alerts.
    pub series: Option<SeriesId>,
    /// Burn rate (burn alerts) or z-score (spikes).
    pub value: f64,
}

impl AnomalyAlert {
    /// Pack kind/scope/series into the trace event's `a` word:
    /// `kind | scope_kind<<8 | scope_idx<<16 | (series+1)<<24`.
    pub fn code(&self) -> u64 {
        let (sk, si) = self.scope.code();
        let series = self.series.map(|s| s as u64 + 1).unwrap_or(0);
        self.kind as u64 | (sk as u64) << 8 | (si as u64) << 16 | series << 24
    }

    /// Inverse of [`AnomalyAlert::code`] (`value` from the event's `b`
    /// word as `f64::from_bits`).
    pub fn from_words(t_us: u64, a: u64, b: u64) -> Option<AnomalyAlert> {
        Some(AnomalyAlert {
            t_us,
            kind: AlertKind::from_code((a & 0xFF) as u8)?,
            scope: AlertScope::from_code(((a >> 8) & 0xFF) as u8, ((a >> 16) & 0xFF) as u8)?,
            series: match ((a >> 24) & 0xFF) as u8 {
                0 => None,
                s => Some(SeriesId::from_code(s - 1)?),
            },
            value: f64::from_bits(b),
        })
    }

    pub fn describe(&self) -> String {
        let scope = match self.scope {
            AlertScope::Global => "global".to_string(),
            AlertScope::Class(i) => format!("class {i}"),
            AlertScope::Tenant(i) => format!("tenant {i}"),
        };
        match self.kind {
            AlertKind::Spike => format!(
                "{} {} spike z={:+.1}",
                scope,
                self.series.map(|s| s.as_str()).unwrap_or("?"),
                self.value
            ),
            k => format!("{scope} {} burn {:.1}x budget", k.as_str(), self.value),
        }
    }
}

/// One tick's worth of observations, assembled by the serve session from
/// its cumulative tallies (the session diffs; the engine only sees
/// deltas).
#[derive(Debug, Clone, Default)]
pub struct TickSample {
    pub t_us: u64,
    /// Per-class `(bad, total)` terminal-outcome deltas this tick.
    pub class_bad: Vec<(u64, u64)>,
    /// Per-tenant `(bad, total)` terminal-outcome deltas this tick.
    pub tenant_bad: Vec<(u64, u64)>,
    /// Instantaneous global series values this tick, indexed by
    /// [`SeriesId`] discriminant order (missing entries are skipped).
    pub series: Vec<(SeriesId, f64)>,
}

/// The engine's per-tick verdict.
#[derive(Debug, Clone, Default)]
pub struct TickVerdict {
    /// Edge-triggered alerts that fired this tick.
    pub alerts: Vec<AnomalyAlert>,
    /// Level signal: true while any burn-window condition holds on any
    /// scope.  The admission governor keys off this, not off alerts, so
    /// it reacts to sustained burn rather than edges.
    pub burning: bool,
}

/// All detector state for one serve run.
pub struct AnomalyEngine {
    budget: f64,
    classes: Vec<BurnScope>,
    tenants: Vec<BurnScope>,
    spikes: Vec<(SeriesId, ZScore, bool)>,
}

impl AnomalyEngine {
    pub fn new(classes: usize, tenants: usize, budget: SloBudget) -> Self {
        AnomalyEngine {
            budget: budget.0,
            classes: (0..classes).map(|_| BurnScope::new()).collect(),
            tenants: (0..tenants).map(|_| BurnScope::new()).collect(),
            spikes: SeriesId::ALL
                .iter()
                .map(|&s| (s, ZScore::new(0.2, 4.0, 10), false))
                .collect(),
        }
    }

    /// Feed one tick; returns the edge alerts plus the burning level.
    pub fn tick(&mut self, sample: &TickSample) -> TickVerdict {
        let mut v = TickVerdict::default();
        for (i, &(bad, total)) in sample.class_bad.iter().enumerate() {
            if i >= self.classes.len() {
                break;
            }
            let (fired, burning) = self.classes[i].push(bad, total, self.budget);
            v.burning |= burning;
            for (w, burn) in fired {
                v.alerts.push(AnomalyAlert {
                    t_us: sample.t_us,
                    kind: if w == 0 { AlertKind::BurnFast } else { AlertKind::BurnSlow },
                    scope: AlertScope::Class(i as u8),
                    series: None,
                    value: burn,
                });
            }
        }
        for (i, &(bad, total)) in sample.tenant_bad.iter().enumerate() {
            if i >= self.tenants.len() {
                break;
            }
            let (fired, burning) = self.tenants[i].push(bad, total, self.budget);
            v.burning |= burning;
            for (w, burn) in fired {
                v.alerts.push(AnomalyAlert {
                    t_us: sample.t_us,
                    kind: if w == 0 { AlertKind::BurnFast } else { AlertKind::BurnSlow },
                    scope: AlertScope::Tenant(i as u8),
                    series: None,
                    value: burn,
                });
            }
        }
        for &(series, x) in &sample.series {
            let Some(slot) =
                self.spikes.iter_mut().find(|(s, _, _)| *s == series)
            else {
                continue;
            };
            let z = slot.1.observe(x);
            // Edge-trigger: one alert per excursion, re-armed once the
            // series returns inside the band.
            if let Some(z) = z {
                if !slot.2 {
                    slot.2 = true;
                    v.alerts.push(AnomalyAlert {
                        t_us: sample.t_us,
                        kind: AlertKind::Spike,
                        scope: AlertScope::Global,
                        series: Some(series),
                        value: z,
                    });
                }
            } else {
                slot.2 = false;
            }
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zscore_flags_step_change_once_warm() {
        let mut d = ZScore::new(0.2, 4.0, 10);
        for _ in 0..20 {
            assert!(d.observe(100.0).is_none(), "flat series must not fire");
        }
        let z = d.observe(1_000.0).expect("step change must fire");
        assert!(z > 4.0);
    }

    #[test]
    fn zscore_warmup_swallows_early_samples() {
        let mut d = ZScore::new(0.2, 4.0, 10);
        for i in 0..10 {
            assert!(d.observe((i * 1000) as f64).is_none(), "sample {i} in warmup");
        }
    }

    #[test]
    fn burn_scope_requires_both_windows() {
        let budget = 0.1;
        let mut s = BurnScope::new();
        // One hot tick inside a cold history: the short window exceeds
        // the factor but the long window dilutes it — no fire.
        for _ in 0..24 {
            s.push(0, 10, budget);
        }
        let (fired, burning) = s.push(10, 10, budget);
        assert!(fired.is_empty(), "single hot tick must not fire: {fired:?}");
        assert!(!burning);
        // Sustained burn lights both windows.
        let mut any = Vec::new();
        for _ in 0..25 {
            let (f, _) = s.push(10, 10, budget);
            any.extend(f);
        }
        assert!(any.iter().any(|&(w, _)| w == 0), "fast pair must fire under sustained burn");
    }

    #[test]
    fn burn_alerts_are_edge_triggered_and_rearm() {
        let budget = 0.1;
        let mut s = BurnScope::new();
        let mut fast_fires = 0;
        for _ in 0..60 {
            let (f, _) = s.push(10, 10, budget);
            fast_fires += f.iter().filter(|&&(w, _)| w == 0).count();
        }
        assert_eq!(fast_fires, 1, "sustained burn fires the fast pair exactly once");
        // Cool down until the short window clears, then burn again.
        for _ in 0..30 {
            s.push(0, 10, budget);
        }
        let mut refired = 0;
        for _ in 0..30 {
            let (f, _) = s.push(10, 10, budget);
            refired += f.iter().filter(|&&(w, _)| w == 0).count();
        }
        assert_eq!(refired, 1, "cleared alert must re-arm");
    }

    #[test]
    fn engine_is_deterministic_and_scoped() {
        let run = || {
            let mut e = AnomalyEngine::new(3, 2, SloBudget::default());
            let mut all = Vec::new();
            for t in 0..80u64 {
                let hot = t >= 30;
                let sample = TickSample {
                    t_us: t * TICK_US,
                    class_bad: vec![
                        (if hot { 8 } else { 0 }, 10),
                        (0, 10),
                        (0, 0),
                    ],
                    tenant_bad: vec![(if hot { 4 } else { 0 }, 10), (0, 10)],
                    series: vec![(SeriesId::Goodput, if hot { 2.0 } else { 90.0 })],
                };
                all.extend(e.tick(&sample).alerts);
            }
            all
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same feed must produce bit-identical alerts");
        assert!(
            a.iter().any(|x| x.scope == AlertScope::Class(0)),
            "burning class must alert: {a:?}"
        );
        assert!(
            !a.iter().any(|x| x.scope == AlertScope::Class(1)),
            "healthy class must stay quiet: {a:?}"
        );
        assert!(
            a.iter().any(|x| x.kind == AlertKind::Spike),
            "goodput collapse must trip the spike detector: {a:?}"
        );
    }

    #[test]
    fn alert_words_roundtrip() {
        let alerts = [
            AnomalyAlert {
                t_us: 700_000,
                kind: AlertKind::BurnFast,
                scope: AlertScope::Class(2),
                series: None,
                value: 9.25,
            },
            AnomalyAlert {
                t_us: 1_200_000,
                kind: AlertKind::Spike,
                scope: AlertScope::Global,
                series: Some(SeriesId::BusDeferRate),
                value: -5.5,
            },
            AnomalyAlert {
                t_us: 0,
                kind: AlertKind::BurnSlow,
                scope: AlertScope::Tenant(1),
                series: None,
                value: 2.125,
            },
        ];
        for a in alerts {
            let got = AnomalyAlert::from_words(a.t_us, a.code(), a.value.to_bits()).unwrap();
            assert_eq!(got, a);
        }
    }

    #[test]
    fn governor_feedback_does_not_count_rate_limited_sheds() {
        // Documented invariant check: the "bad" definition is assembled
        // by the session, but the engine must stay quiet when fed zero
        // bad (i.e. when only rate-limited sheds occur the session
        // reports bad=0 and the loop cannot self-sustain).
        let mut e = AnomalyEngine::new(1, 1, SloBudget::default());
        let mut burning_ticks = 0;
        for t in 0..200u64 {
            let sample = TickSample {
                t_us: t * TICK_US,
                class_bad: vec![(0, 10)],
                tenant_bad: vec![(0, 10)],
                series: Vec::new(),
            };
            let v = e.tick(&sample);
            assert!(v.alerts.is_empty());
            burning_ticks += v.burning as u32;
        }
        assert_eq!(burning_ticks, 0);
    }
}
