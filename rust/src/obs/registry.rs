//! The metrics registry: named counters, gauges, and log-bucketed
//! histograms with one publication surface.
//!
//! Serve, engine, and vdisk layers each used to keep private tallies
//! (`SloTracker` counts, `CacheStats`, `DecodeStats`) that reports had to
//! chase individually.  The registry is the one place those numbers land:
//! `count`/`gauge`/`observe` on the hot path, [`MetricsRegistry::snapshot`]
//! at the end of a run.
//!
//! Determinism: names live in `BTreeMap`s, so a snapshot's iteration order
//! is the lexicographic name order — never `HashMap` bucket order — and a
//! same-seed run snapshots bit-identically.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::metrics::Histogram;

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    /// Gauge = (last set value, max ever set).
    gauges: BTreeMap<String, (u64, u64)>,
    hists: BTreeMap<String, Histogram>,
}

/// Shared, mutex-guarded metrics store.  Clones share the inner maps; the
/// default value is a live, empty registry.
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    inner: Arc<Mutex<Inner>>,
}

impl MetricsRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n` to the named counter (creating it at 0).  The steady-state
    /// path (key already present) does not allocate.
    pub fn count(&self, name: &str, n: u64) {
        let mut inner = self.inner.lock().unwrap();
        match inner.counters.get_mut(name) {
            Some(v) => *v += n,
            None => {
                inner.counters.insert(name.to_string(), n);
            }
        }
    }

    /// Set the named gauge; its max-ever value is tracked alongside.
    pub fn gauge(&self, name: &str, v: u64) {
        let mut inner = self.inner.lock().unwrap();
        match inner.gauges.get_mut(name) {
            Some(g) => {
                g.0 = v;
                g.1 = g.1.max(v);
            }
            None => {
                inner.gauges.insert(name.to_string(), (v, v));
            }
        }
    }

    /// Record one sample into the named log-bucketed histogram.
    pub fn observe(&self, name: &str, v_us: u64) {
        let mut inner = self.inner.lock().unwrap();
        match inner.hists.get_mut(name) {
            Some(h) => h.record(v_us),
            None => {
                let mut h = Histogram::default();
                h.record(v_us);
                inner.hists.insert(name.to_string(), h);
            }
        }
    }

    /// Current counter value (0 if never touched).
    pub fn counter_value(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// A point-in-time copy, sorted by metric name.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        MetricsSnapshot {
            counters: inner.counters.iter().map(|(k, v)| (k.clone(), *v)).collect(),
            gauges: inner.gauges.iter().map(|(k, (last, max))| (k.clone(), *last, *max)).collect(),
            hists: inner
                .hists
                .iter()
                .map(|(k, h)| (k.clone(), HistSummary::of(h)))
                .collect(),
        }
    }
}

impl std::fmt::Debug for MetricsRegistry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let inner = self.inner.lock().unwrap();
        write!(
            f,
            "MetricsRegistry({} counters, {} gauges, {} hists)",
            inner.counters.len(),
            inner.gauges.len(),
            inner.hists.len()
        )
    }
}

/// The five numbers a histogram is worth at report time.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct HistSummary {
    pub count: u64,
    pub mean_us: u64,
    pub p50_us: u64,
    pub p99_us: u64,
    pub max_us: u64,
}

impl HistSummary {
    fn of(h: &Histogram) -> Self {
        HistSummary {
            count: h.count(),
            mean_us: h.mean_us() as u64,
            // Interpolated within the log bucket: error bounded by one
            // bucket (factor of 2), clamped to observed [min, max] —
            // see `Histogram::percentile_us`.
            p50_us: h.percentile_us(50.0),
            p99_us: h.percentile_us(99.0),
            max_us: h.max_us(),
        }
    }
}

/// Name-sorted copy of the registry at one instant.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: Vec<(String, u64)>,
    /// (name, last value, max-ever value).
    pub gauges: Vec<(String, u64, u64)>,
    pub hists: Vec<(String, HistSummary)>,
}

impl MetricsSnapshot {
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(k, _)| k == name).map(|(_, v)| *v).unwrap_or(0)
    }

    pub fn gauge_max(&self, name: &str) -> u64 {
        self.gauges.iter().find(|(k, _, _)| k == name).map(|(_, _, m)| *m).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_read_back() {
        let reg = MetricsRegistry::new();
        reg.count("serve.shed.rate_limited", 3);
        reg.count("serve.shed.rate_limited", 2);
        reg.count("serve.offered", 1);
        assert_eq!(reg.counter_value("serve.shed.rate_limited"), 5);
        assert_eq!(reg.counter_value("never.touched"), 0);
        let snap = reg.snapshot();
        assert_eq!(snap.counter("serve.offered"), 1);
    }

    #[test]
    fn gauges_track_last_and_max() {
        let reg = MetricsRegistry::new();
        reg.gauge("serve.queue_depth", 4);
        reg.gauge("serve.queue_depth", 9);
        reg.gauge("serve.queue_depth", 2);
        let snap = reg.snapshot();
        assert_eq!(snap.gauges, vec![("serve.queue_depth".to_string(), 2, 9)]);
        assert_eq!(snap.gauge_max("serve.queue_depth"), 9);
    }

    #[test]
    fn histograms_summarize() {
        let reg = MetricsRegistry::new();
        for v in [100u64, 200, 400, 800] {
            reg.observe("serve.latency_us", v);
        }
        let snap = reg.snapshot();
        let (name, h) = &snap.hists[0];
        assert_eq!(name, "serve.latency_us");
        assert_eq!(h.count, 4);
        assert_eq!(h.max_us, 800);
        assert!(h.p99_us >= 800, "p99 upper bound covers the max sample");
    }

    #[test]
    fn summary_quantiles_are_within_one_bucket_of_exact() {
        let exact = |sorted: &[u64], p: f64| -> u64 {
            let idx =
                ((sorted.len() as f64 * p / 100.0).ceil() as usize).clamp(1, sorted.len()) - 1;
            sorted[idx]
        };
        // Adversarial shapes: all mass in one bucket, a cross-bucket
        // ramp, and a bimodal split across the range.
        let shapes: [(&str, Vec<u64>); 3] = [
            ("constant", vec![777; 500]),
            ("ramp", (1..=2048).collect()),
            ("bimodal", {
                let mut v = vec![25u64; 950];
                v.extend(vec![64_000u64; 50]);
                v
            }),
        ];
        for (name, mut vals) in shapes {
            let reg = MetricsRegistry::new();
            for &v in &vals {
                reg.observe("q", v);
            }
            vals.sort_unstable();
            let snap = reg.snapshot();
            let h = &snap.hists[0].1;
            for (p, got) in [(50.0, h.p50_us), (99.0, h.p99_us)] {
                let e = exact(&vals, p);
                assert!(
                    got >= (e / 2).max(vals[0]) && got <= e.saturating_mul(2).min(h.max_us),
                    "{name} p{p}: got {got}, exact {e}"
                );
            }
        }
    }

    #[test]
    fn snapshot_order_is_name_sorted_not_insertion() {
        let reg = MetricsRegistry::new();
        reg.count("zz", 1);
        reg.count("aa", 1);
        reg.count("mm", 1);
        let names: Vec<_> = reg.snapshot().counters.into_iter().map(|(k, _)| k).collect();
        assert_eq!(names, vec!["aa", "mm", "zz"]);
    }

    #[test]
    fn clones_share_storage() {
        let reg = MetricsRegistry::new();
        let c = reg.clone();
        c.count("shared", 7);
        assert_eq!(reg.counter_value("shared"), 7);
    }
}
