//! Minimal JSON parser/emitter (serde is unavailable offline).
//!
//! Covers the subset CHAMP needs: the artifact manifest written by
//! `python/compile/aot.py`, the system config files, and the ComfyUI-style
//! workflow export.  Numbers are f64 (like JavaScript); object key order is
//! preserved for stable, diffable output.

mod parse;

use std::fmt::Write as _;

pub use parse::{parse, ParseError};

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    /// Insertion-ordered object.
    Obj(Vec<(String, Value)>),
}

impl Value {
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|n| n as u64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Emit compact JSON.
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Emit pretty-printed JSON with 2-space indent.
    pub fn to_json_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => ("\n", " ".repeat(w * depth), " ".repeat(w * (depth + 1))),
            None => ("", String::new(), String::new()),
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Value::Str(s) => write_escaped(out, s),
            Value::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Value::Obj(kv) => {
                if kv.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(kv: Vec<(&str, Value)>) -> Value {
    Value::Obj(kv.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Value {
    Value::Num(n)
}

pub fn s(v: &str) -> Value {
    Value::Str(v.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let v = obj(vec![
            ("name", s("champ")),
            ("n", num(5.0)),
            ("list", Value::Arr(vec![num(1.0), num(2.5), Value::Null])),
            ("flag", Value::Bool(true)),
            ("inner", obj(vec![("k", s("v"))])),
        ]);
        let text = v.to_json();
        let back = parse(&text).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn pretty_roundtrip() {
        let v = obj(vec![("a", Value::Arr(vec![num(1.0)])), ("b", obj(vec![]))]);
        let back = parse(&v.to_json_pretty()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn escapes_strings() {
        let v = s("a\"b\\c\nd");
        let text = v.to_json();
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn integers_emit_without_fraction() {
        assert_eq!(num(5.0).to_json(), "5");
        assert_eq!(num(5.5).to_json(), "5.5");
    }

    #[test]
    fn accessors() {
        let v = obj(vec![("x", num(3.0)), ("s", s("t"))]);
        assert_eq!(v.get("x").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("s").unwrap().as_str(), Some("t"));
        assert!(v.get("missing").is_none());
    }
}
