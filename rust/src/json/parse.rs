//! Recursive-descent JSON parser.

use super::Value;

/// Parse failure with byte offset context.
/// (Manual impls: `thiserror` is not in the vendored dependency set.)
#[derive(Debug)]
pub struct ParseError {
    pub at: usize,
    pub msg: String,
}

impl std::fmt::Display for ParseError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for ParseError {}

struct P<'a> {
    b: &'a [u8],
    i: usize,
}

/// Parse a complete JSON document (trailing whitespace allowed).
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = P { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

impl<'a> P<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'n') => self.lit("null", Value::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(kv));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || c == b'.' || c == b'e' || c == b'E'
                || c == b'+' || c == b'-'
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Value::Num)
            .ok_or_else(|| self.err("invalid number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Value::Num(42.0));
        assert_eq!(parse("-1.5e2").unwrap(), Value::Num(-150.0));
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Value::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse(r#""A""#).unwrap(), Value::Str("A".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(parse("\"héllo\"").unwrap(), Value::Str("héllo".into()));
    }
}
