//! Power & energy model (paper §4.3).
//!
//! The paper extrapolates system power from device specs: each NCS2 draws
//! 1-2 W active, five sticks ≈ 7-8 W, whole system ≈ 10 W — an order of
//! magnitude under a GPU system of similar throughput.  This module
//! integrates per-device power states over the simulated timeline so the
//! power bench can regenerate those numbers (and the GPU comparison).

use crate::device::timing::{DeviceProfile, HostProfile};

/// Power integration over a run.
#[derive(Debug, Clone)]
pub struct PowerModel {
    pub host: HostProfile,
}

impl Default for PowerModel {
    fn default() -> Self {
        PowerModel { host: HostProfile::orin() }
    }
}

/// Energy/power summary for one run.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerReport {
    pub device_w: f64,
    pub host_w: f64,
    pub total_w: f64,
    pub energy_j: f64,
    /// Frames per joule — the efficiency figure of merit.
    pub frames_per_joule: f64,
}

impl PowerModel {
    /// Average power given per-device (busy_us, profile) over a horizon.
    pub fn report(
        &self,
        devices: &[(u64, DeviceProfile)],
        horizon_us: u64,
        frames: u64,
    ) -> PowerReport {
        let horizon_s = (horizon_us.max(1)) as f64 / 1e6;
        let mut device_w = 0.0;
        for (busy_us, prof) in devices {
            let duty = (*busy_us as f64 / horizon_us.max(1) as f64).min(1.0);
            device_w += prof.active_w * duty + prof.idle_w * (1.0 - duty);
        }
        let host_w = self.host.base_w + self.host.per_device_w * devices.len() as f64;
        let total_w = device_w + host_w;
        let energy_j = total_w * horizon_s;
        PowerReport {
            device_w,
            host_w,
            total_w,
            energy_j,
            frames_per_joule: if energy_j > 0.0 { frames as f64 / energy_j } else { 0.0 },
        }
    }

    /// Reference GPU-based system at similar throughput (paper's "order of
    /// magnitude" comparison): a discrete embedded GPU board.
    pub fn gpu_baseline_w() -> f64 {
        95.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_ncs2_match_paper_envelope() {
        // Paper §4.3: five sticks ~7-8 W, total system ~10 W.
        let pm = PowerModel::default();
        let prof = DeviceProfile::ncs2();
        // Near-full duty over 10 s.
        let devices: Vec<(u64, DeviceProfile)> = (0..5).map(|_| (9_500_000, prof)).collect();
        let rep = pm.report(&devices, 10_000_000, 60);
        assert!((7.0..9.5).contains(&rep.device_w), "device_w {}", rep.device_w);
        assert!((9.0..12.0).contains(&rep.total_w), "total_w {}", rep.total_w);
    }

    #[test]
    fn order_of_magnitude_under_gpu() {
        let pm = PowerModel::default();
        let prof = DeviceProfile::ncs2();
        let devices: Vec<(u64, DeviceProfile)> = (0..5).map(|_| (9_000_000, prof)).collect();
        let rep = pm.report(&devices, 10_000_000, 60);
        assert!(PowerModel::gpu_baseline_w() / rep.total_w >= 8.0);
    }

    #[test]
    fn idle_devices_draw_idle_power() {
        let pm = PowerModel::default();
        let rep = pm.report(&[(0, DeviceProfile::ncs2())], 1_000_000, 0);
        assert!((rep.device_w - DeviceProfile::ncs2().idle_w).abs() < 1e-9);
    }

    #[test]
    fn energy_scales_with_horizon() {
        let pm = PowerModel::default();
        let d = [(500_000u64, DeviceProfile::coral())];
        let r1 = pm.report(&d, 1_000_000, 10);
        let d2 = [(1_000_000u64, DeviceProfile::coral())];
        let r2 = pm.report(&d2, 2_000_000, 20);
        assert!((r2.energy_j / r1.energy_j - 2.0).abs() < 0.05);
    }
}
