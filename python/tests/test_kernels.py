"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (including awkward non-multiples of the block
sizes, which exercise the padding paths) and value ranges.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import cosine, dwconv, matmul, quant, ref

SETTINGS = dict(max_examples=12, deadline=None)


def rnd(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------- matmul ---

@settings(**SETTINGS)
@given(
    m=st.integers(1, 90), k=st.integers(1, 160), n=st.integers(1, 150),
    act=st.sampled_from(["none", "relu", "relu6"]), seed=st.integers(0, 2**31),
)
def test_matmul_bias_matches_ref(m, k, n, act, seed):
    x = rnd(seed, (m, k))
    y = rnd(seed + 1, (k, n))
    b = rnd(seed + 2, (n,))
    got = matmul.matmul_bias(x, y, b, act)
    want = ref.matmul_bias(x, y, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(m=st.integers(1, 70), k=st.integers(1, 140), n=st.integers(1, 70),
       seed=st.integers(0, 2**31))
def test_matmul_int8_exact(m, k, n, seed):
    kx, ky = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.randint(kx, (m, k), -128, 128, jnp.int8)
    y = jax.random.randint(ky, (k, n), -128, 128, jnp.int8)
    np.testing.assert_array_equal(matmul.matmul_int8(x, y), ref.matmul_int8(x, y))


def test_matmul_relu6_saturates():
    x = jnp.ones((4, 4)) * 100.0
    y = jnp.eye(4)
    b = jnp.zeros(4)
    out = matmul.matmul_bias(x, y, b, "relu6")
    assert float(out.max()) == 6.0 and float(out.min()) == 6.0


def test_matmul_block_bigger_than_input():
    x = rnd(0, (2, 3))
    y = rnd(1, (3, 2))
    b = rnd(2, (2,))
    np.testing.assert_allclose(
        matmul.matmul_bias(x, y, b), ref.matmul_bias(x, y, b), rtol=1e-5, atol=1e-6)


def test_matmul_vmem_report_within_budget():
    rep = matmul.vmem_report(1024, 1024, 1024)
    assert rep["vmem_ok"], rep
    assert rep["flops"] == 2 * 1024 ** 3
    assert 0 < rep["mxu_utilization_est"] <= 1


# ---------------------------------------------------------------- dwconv ---

@settings(**SETTINGS)
@given(h=st.integers(2, 20), w=st.integers(2, 20), c=st.integers(1, 70),
       relu6=st.booleans(), seed=st.integers(0, 2**31))
def test_depthwise3x3_matches_ref(h, w, c, relu6, seed):
    x = rnd(seed, (h, w, c))
    wt = rnd(seed + 1, (3, 3, c))
    b = rnd(seed + 2, (c,))
    got = dwconv.depthwise3x3(x, wt, b, relu6)
    want = ref.depthwise3x3(x, wt, b, relu6)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_depthwise3x3_identity_kernel():
    """A delta kernel at the center must reproduce the input."""
    x = rnd(7, (8, 8, 16), 2.0)
    wt = jnp.zeros((3, 3, 16)).at[1, 1, :].set(1.0)
    b = jnp.zeros(16)
    np.testing.assert_allclose(
        dwconv.depthwise3x3(x, wt, b, relu6=False), x, rtol=1e-6, atol=1e-6)


def test_depthwise3x3_vmem_budget():
    rep = dwconv.vmem_report(48, 48, 96)
    assert rep["vmem_ok"], rep


# ---------------------------------------------------------------- cosine ---

@settings(**SETTINGS)
@given(b=st.integers(1, 8), g=st.integers(1, 600), d=st.sampled_from([32, 64, 128]),
       seed=st.integers(0, 2**31))
def test_cosine_scores_matches_ref(b, g, d, seed):
    p = rnd(seed, (b, d))
    gal = rnd(seed + 1, (g, d))
    got = cosine.cosine_scores(p, gal)
    want = ref.cosine_scores(p, gal)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_cosine_self_match_is_one():
    gal = rnd(3, (50, 128))
    scores = cosine.cosine_scores(gal[:5], gal)
    for i in range(5):
        assert scores[i].argmax() == i
        assert abs(float(scores[i, i]) - 1.0) < 1e-5


def test_cosine_scores_bounded():
    p = rnd(0, (4, 64), 10.0)
    gal = rnd(1, (200, 64), 0.1)
    s = cosine.cosine_scores(p, gal)
    assert float(jnp.abs(s).max()) <= 1.0 + 1e-5


# ------------------------------------------------------------- secure ------

@settings(**SETTINGS)
@given(b=st.integers(1, 4), g=st.integers(1, 300), seed=st.integers(0, 2**31))
def test_secure_match_equals_plaintext(b, g, seed):
    """Orthogonal rotation preserves cosine scores: the template-protection
    scheme must be score-invariant (the paper's HE-matching claim)."""
    d = 64
    p = rnd(seed, (b, d))
    gal = rnd(seed + 1, (g, d))
    q, _ = jnp.linalg.qr(rnd(seed + 2, (d, d)))
    got = cosine.secure_scores(p, q, gal @ q)
    want = ref.cosine_scores(p, gal)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)


def test_secure_scores_matches_its_own_ref():
    p = rnd(0, (2, 64))
    gal = rnd(1, (100, 64))
    q, _ = jnp.linalg.qr(rnd(2, (64, 64)))
    np.testing.assert_allclose(
        cosine.secure_scores(p, q, gal @ q),
        ref.secure_scores(p, q, gal @ q), rtol=1e-4, atol=1e-5)


def test_rotated_gallery_hides_templates():
    """Sanity: the rotated gallery is NOT the plaintext gallery."""
    gal = rnd(1, (100, 64))
    q, _ = jnp.linalg.qr(rnd(2, (64, 64)))
    assert float(jnp.abs(gal @ q - gal).max()) > 0.1


# ---------------------------------------------------------------- quant ----

@settings(**SETTINGS)
@given(n=st.integers(1, 9000), scale=st.floats(0.01, 0.5), zp=st.integers(-10, 10),
       seed=st.integers(0, 2**31))
def test_quantize_matches_ref(n, scale, zp, seed):
    x = rnd(seed, (n,), 3.0)
    np.testing.assert_array_equal(
        quant.quantize(x, scale, zp), ref.quantize(x, scale, zp))


@settings(**SETTINGS)
@given(n=st.integers(1, 9000), scale=st.floats(0.01, 0.5), seed=st.integers(0, 2**31))
def test_dequantize_roundtrip_within_half_step(n, scale, seed):
    """Round-trip error is bounded by scale/2 for in-range values."""
    x = jnp.clip(rnd(seed, (n,), 2.0), -126 * scale, 126 * scale)
    rt = quant.dequantize(quant.quantize(x, scale), scale)
    assert float(jnp.abs(rt - x).max()) <= scale / 2 + 1e-6


def test_quantize_saturates():
    x = jnp.array([1e6, -1e6], jnp.float32)
    q = quant.quantize(x, 0.1)
    assert int(q[0]) == 127 and int(q[1]) == -128


def test_calibrate_scale_reasonable():
    x = rnd(0, (10000,), 1.0)
    s = quant.calibrate_scale(x)
    assert 0.001 < s < 1.0
