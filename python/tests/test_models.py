"""L2 correctness: cartridge model contracts (shapes, ranges, invariants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def img(seed, shape=(96, 96, 3)):
    return jax.random.uniform(jax.random.PRNGKey(seed), shape)


# ----------------------------------------------------------- detection -----

def test_mobilenet_det_shapes():
    boxes, logits = model.mobilenet_v2_det(img(0))
    assert boxes.shape == (72, 4)
    assert logits.shape == (72, model.NUM_CLASSES)


def test_mobilenet_det_boxes_in_unit_range():
    boxes, _ = model.mobilenet_v2_det(img(1))
    assert float(boxes.min()) >= 0.0 and float(boxes.max()) <= 1.0


def test_mobilenet_det_deterministic():
    a = model.mobilenet_v2_det(img(2))
    b = model.mobilenet_v2_det(img(2))
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])


def test_mobilenet_det_int8_close_to_f32():
    """The quantized cartridge must agree with fp32 at the decision level:
    per-anchor argmax class mostly unchanged."""
    x = img(3)
    _, lg32 = model.mobilenet_v2_det(x, int8=False)
    _, lg8 = model.mobilenet_v2_det(x, int8=True)
    agree = float(jnp.mean((jnp.argmax(lg32, -1) == jnp.argmax(lg8, -1))))
    assert agree >= 0.7, f"int8/f32 class agreement too low: {agree}"


def test_retinaface_shapes():
    scores, boxes, lmk = model.retinaface_det(img(4))
    assert scores.shape == (36,)
    assert boxes.shape == (36, 4)
    assert lmk.shape == (36, 10)
    assert float(boxes.min()) >= 0.0 and float(boxes.max()) <= 1.0


# ----------------------------------------------------------- embeddings ----

def test_facenet_embedding_normalized():
    (emb,) = model.facenet_embed(img(5, (64, 64, 3)))
    assert emb.shape == (model.EMBED_DIM,)
    assert abs(float(jnp.linalg.norm(emb)) - 1.0) < 1e-4


def test_facenet_embedding_discriminative():
    """Different inputs produce different embeddings; same input, same."""
    (e1,) = model.facenet_embed(img(6, (64, 64, 3)))
    (e2,) = model.facenet_embed(img(7, (64, 64, 3)))
    (e1b,) = model.facenet_embed(img(6, (64, 64, 3)))
    assert float(jnp.abs(e1 - e1b).max()) == 0.0
    assert float(jnp.dot(e1, e2)) < 0.999


def test_gaitset_embedding_normalized():
    (emb,) = model.gaitset_embed(img(8, (8, 32, 32)))
    assert emb.shape == (model.GAIT_DIM,)
    assert abs(float(jnp.linalg.norm(emb)) - 1.0) < 1e-4


def test_gaitset_set_pooling_permutation_invariant():
    """GaitSet treats the gait sequence as a SET: frame order must not
    change the embedding (max-pool over the set dimension)."""
    sils = img(9, (8, 32, 32))
    (e1,) = model.gaitset_embed(sils)
    (e2,) = model.gaitset_embed(sils[::-1])
    np.testing.assert_allclose(e1, e2, atol=1e-6)


def test_quality_in_unit_interval():
    for seed in range(4):
        (q,) = model.crfiqa_quality(img(10 + seed, (64, 64, 3)))
        assert q.shape == (1,)
        assert 0.0 <= float(q[0]) <= 1.0


# ----------------------------------------------------------- matchers ------

def _gallery(seed, g=256, d=model.EMBED_DIM):
    gal = jax.random.normal(jax.random.PRNGKey(seed), (g, d))
    return gal / jnp.linalg.norm(gal, axis=1, keepdims=True)


def test_gallery_match_finds_planted_probe():
    gal = _gallery(20)
    probe = gal[37:38]
    scores, best, best_score = model.gallery_match(probe, gal)
    assert scores.shape == (1, 256)
    assert int(best[0]) == 37
    assert abs(float(best_score[0]) - 1.0) < 1e-4


def test_gallery_match_noisy_probe_still_rank1():
    gal = _gallery(21)
    noise = 0.1 * jax.random.normal(jax.random.PRNGKey(99), (1, model.EMBED_DIM))
    probe = gal[5:6] + noise
    _, best, _ = model.gallery_match(probe, gal)
    assert int(best[0]) == 5


def test_secure_match_same_decision_as_plaintext():
    gal = _gallery(22)
    q, _ = jnp.linalg.qr(jax.random.normal(jax.random.PRNGKey(1), (128, 128)))
    probe = gal[11:12] + 0.05 * jax.random.normal(jax.random.PRNGKey(2), (1, 128))
    s_plain, best_plain, _ = model.gallery_match(probe, gal)
    s_sec, best_sec, _ = model.secure_gallery_match(probe, q, gal @ q)
    assert int(best_plain[0]) == int(best_sec[0]) == 11
    np.testing.assert_allclose(s_plain, s_sec, rtol=1e-3, atol=1e-4)


# ----------------------------------------------------------- registry ------

def test_registry_covers_paper_cartridges():
    """Section 3.2's cartridge list must be present in the AOT registry."""
    names = set(model.REGISTRY)
    for required in ["mobilenet_v2_det", "retinaface_det", "facenet_embed",
                     "crfiqa_quality", "gaitset_embed", "gallery_match",
                     "secure_gallery_match"]:
        assert required in names


def test_registry_example_shapes_run():
    """eval_shape of every registry entry agrees with its example spec."""
    for name, (fn, example_in, _) in model.REGISTRY.items():
        out = jax.eval_shape(fn, *example_in)
        assert out is not None, name
