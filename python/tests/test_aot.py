"""AOT path: HLO-text emission + manifest consistency.

These tests lower the two smallest registry entries end-to-end (the full set
is exercised by `make artifacts`) and validate the manifest contract the
Rust runtime depends on.
"""

import json
import os

import jax
import pytest

from compile import aot, model

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_hlo_text_is_parseable_entry(tmp_path):
    entry = aot.lower_one("gallery_match", str(tmp_path))
    text = (tmp_path / "gallery_match.hlo.txt").read_text()
    assert "ENTRY" in text and "HloModule" in text
    # Tuple-root: rust unwraps a 3-tuple for this model.
    assert len(entry["outputs"]) == 3


def test_manifest_entry_shapes_match_registry(tmp_path):
    entry = aot.lower_one("crfiqa_quality", str(tmp_path))
    assert entry["inputs"] == [{"shape": [64, 64, 3], "dtype": "f32"}]
    assert entry["outputs"] == [{"shape": [1], "dtype": "f32"}]
    assert entry["sha256"] and entry["hlo_bytes"] > 0


def test_kernel_reports_all_within_vmem_budget():
    reports = aot.kernel_reports()
    assert reports, "no kernel reports"
    for name, rep in reports.items():
        assert rep["vmem_ok"], f"{name} exceeds VMEM budget: {rep}"


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_built_manifest_consistent_with_files():
    with open(os.path.join(ART, "manifest.json")) as f:
        manifest = json.load(f)
    names = {m["name"] for m in manifest["models"]}
    assert names == set(model.REGISTRY)
    for m in manifest["models"]:
        path = os.path.join(ART, m["file"])
        assert os.path.exists(path), path
        assert os.path.getsize(path) == m["hlo_bytes"]


def test_dtype_map_covers_registry():
    import jax.numpy as jnp
    for name, (fn, example_in, _) in model.REGISTRY.items():
        out = jax.eval_shape(fn, *example_in)
        if not isinstance(out, (tuple, list)):
            out = (out,)
        for s in list(example_in) + list(out):
            assert jnp.dtype(s.dtype) in aot._DTYPE, (name, s.dtype)
