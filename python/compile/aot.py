"""AOT compiler: lower every cartridge model to HLO text + manifest.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``).  The text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model ``name`` in ``model.REGISTRY``:
  artifacts/<name>.hlo.txt      -- the lowered module
  artifacts/manifest.json       -- input/output shapes+dtypes for the Rust
                                   runtime, plus FLOPs and VMEM reports.

Usage: python -m compile.aot --out ../artifacts [--only name]
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import cosine as kcos
from .kernels import dwconv as kdw
from .kernels import matmul as kmm

_DTYPE = {
    jnp.float32.dtype: "f32",
    jnp.int32.dtype: "i32",
    jnp.int8.dtype: "i8",
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(s) -> dict:
    return {"shape": list(s.shape), "dtype": _DTYPE[jnp.dtype(s.dtype)]}


def lower_one(name: str, out_dir: str) -> dict:
    fn, example_in, desc = model.REGISTRY[name]
    t0 = time.time()
    lowered = jax.jit(fn).lower(*example_in)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    out_shapes = jax.eval_shape(fn, *example_in)
    if not isinstance(out_shapes, (tuple, list)):
        out_shapes = (out_shapes,)
    entry = {
        "name": name,
        "description": desc,
        "file": f"{name}.hlo.txt",
        "inputs": [_spec(s) for s in example_in],
        "outputs": [_spec(s) for s in out_shapes],
        "hlo_bytes": len(text),
        "sha256": hashlib.sha256(text.encode()).hexdigest(),
        "lower_seconds": round(time.time() - t0, 2),
    }
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO in {entry['lower_seconds']}s",
          flush=True)
    return entry


def kernel_reports() -> dict:
    """Static VMEM/MXU tiling reports for the perf section of DESIGN.md."""
    return {
        "matmul_pointwise_6x6x96_to_128": kmm.vmem_report(36, 128, 96),
        "matmul_fc_2048_to_128": kmm.vmem_report(1, 128, 2048),
        "matmul_gemm_1024": kmm.vmem_report(1024, 1024, 1024),
        "dwconv_48x48x96": kdw.vmem_report(48, 48, 96),
        "cosine_gallery_1024x128": kcos.vmem_report(1, 1024, 128),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="lower a single model from the registry")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    names = [args.only] if args.only else list(model.REGISTRY)
    entries = []
    for name in names:
        if name not in model.REGISTRY:
            sys.exit(f"unknown model {name!r}; have {list(model.REGISTRY)}")
        entries.append(lower_one(name, args.out))

    manifest = {
        "format": "hlo-text-v1",
        "models": entries,
        "kernel_reports": kernel_reports(),
        "constants": {
            "embed_dim": model.EMBED_DIM,
            "gait_dim": model.GAIT_DIM,
            "gallery_size": model.GALLERY_SIZE,
            "num_classes": model.NUM_CLASSES,
            "gait_frames": model.GAIT_FRAMES,
        },
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
