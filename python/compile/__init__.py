"""CHAMP build-time compile path (L2 models + L1 kernels + AOT)."""
