"""Depthwise 3x3 convolution Pallas kernel.

MobileNetV2's inverted-residual blocks spend most of their non-GEMM time in
depthwise 3x3 convolutions.  On the Myriad-X-class cartridge this runs on the
vector (VPU/VMEM) units rather than the MAC array, so the kernel is written
as nine shifted multiply-accumulates over a channel-blocked layout: the grid
walks channel blocks, each program holding a (H+2, W+2, bc) input tile and a
(H, W, bc) output tile in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _dw_kernel(x_ref, w_ref, b_ref, o_ref, *, h: int, w: int, relu6: bool):
    acc = jnp.zeros(o_ref.shape, jnp.float32)
    # Nine static shifts -- the VPU-friendly formulation of a 3x3 stencil.
    for dy in range(3):
        for dx in range(3):
            acc = acc + x_ref[dy:dy + h, dx:dx + w, :] * w_ref[dy, dx, :]
    acc = acc + b_ref[0, 0, :]
    if relu6:
        acc = jnp.clip(acc, 0.0, 6.0)
    o_ref[...] = acc


def depthwise3x3(x, w, b, relu6: bool = True, bc: int = 32):
    """Depthwise 3x3, stride 1, SAME padding.

    x: (H, W, C) f32, w: (3, 3, C) f32, b: (C,) f32 -> (H, W, C) f32.
    """
    h, wd, c = x.shape
    assert w.shape == (3, 3, c)
    bc = common.pick_block(c, bc)
    cp = common.round_up(c, bc)
    xp = common.pad_axis(x, 2, cp)
    wp = common.pad_axis(w, 2, cp)
    bp = common.pad_axis(b, 0, cp).reshape(1, 1, cp)
    # SAME halo for the 3x3 stencil.
    xp = jnp.pad(xp, ((1, 1), (1, 1), (0, 0)))

    grid = (cp // bc,)
    out = pl.pallas_call(
        functools.partial(_dw_kernel, h=h, w=wd, relu6=relu6),
        grid=grid,
        in_specs=[
            pl.BlockSpec((h + 2, wd + 2, bc), lambda i: (0, 0, i)),
            pl.BlockSpec((3, 3, bc), lambda i: (0, 0, i)),
            pl.BlockSpec((1, 1, bc), lambda i: (0, 0, i)),
        ],
        out_specs=pl.BlockSpec((h, wd, bc), lambda i: (0, 0, i)),
        out_shape=jax.ShapeDtypeStruct((h, wd, cp), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:, :, :c]


def vmem_report(h: int, w: int, c: int, bc: int = 32) -> dict:
    bc = common.pick_block(c, bc)
    vmem = common.block_vmem_bytes((h + 2, w + 2, bc), (h, w, bc))
    return {
        "block": [h, w, bc],
        "vmem_bytes": vmem,
        "vmem_ok": vmem <= common.VMEM_BUDGET_BYTES,
        "flops": 2 * 9 * h * w * c,
    }
