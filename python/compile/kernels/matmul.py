"""Tiled matmul Pallas kernels: the CHAMP cartridge compute workhorse.

Every pointwise (1x1) convolution and fully-connected layer in the cartridge
model zoo lowers to ``matmul_bias`` -- an (M,K)x(K,N) GEMM with fused bias
and optional ReLU6, tiled so each (bm,bk)+(bk,bn)+(bm,bn) working set fits
the VMEM budget and the inner dims are MXU-lane aligned where possible.

An int8 variant (``matmul_int8``) accumulates in int32, mirroring the Edge
TPU's quantized execution path; it is used by the quantized model variants
and the quantization ablation bench.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _mm_kernel(x_ref, y_ref, b_ref, o_ref, *, nsteps: int, activation: str):
    """Grid = (M/bm, N/bn, K/bk); accumulate over the K axis of the grid."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nsteps - 1)
    def _finish():
        acc = o_ref[...] + b_ref[...]
        if activation == "relu6":
            acc = jnp.clip(acc, 0.0, 6.0)
        elif activation == "relu":
            acc = jnp.maximum(acc, 0.0)
        o_ref[...] = acc


def matmul_bias(x, y, b, activation: str = "none",
                bm: int = 64, bn: int = common.LANE, bk: int = common.LANE):
    """``activation(x @ y + b)`` with a VMEM-tiled Pallas kernel.

    x: (M, K) f32, y: (K, N) f32, b: (N,) f32 -> (M, N) f32.
    Arbitrary M/N/K are handled by zero-padding up to the block grid.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert b.shape == (n,), f"bias shape {b.shape} != ({n},)"

    bm = common.pick_block(m, bm)
    bn = common.pick_block(n, bn)
    bk = common.pick_block(k, bk)
    mp, np_, kp = (common.round_up(m, bm), common.round_up(n, bn),
                   common.round_up(k, bk))
    xp = common.pad_axis(common.pad_axis(x, 0, mp), 1, kp)
    yp = common.pad_axis(common.pad_axis(y, 0, kp), 1, np_)
    bp = common.pad_axis(b, 0, np_).reshape(1, np_)

    grid = (mp // bm, np_ // bn, kp // bk)
    kernel = functools.partial(_mm_kernel, nsteps=grid[2], activation=activation)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp, bp)
    return out[:m, :n]


def _mm_int8_kernel(x_ref, y_ref, o_ref, *, nsteps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...].astype(jnp.int32),
        y_ref[...].astype(jnp.int32),
        preferred_element_type=jnp.int32,
    )


def matmul_int8(x, y, bm: int = 64, bn: int = common.LANE, bk: int = common.LANE):
    """int8 x int8 -> int32 GEMM, the Edge-TPU-style quantized inner loop.

    x: (M, K) int8, y: (K, N) int8 -> (M, N) int32.
    """
    m, k = x.shape
    _, n = y.shape
    bm = common.pick_block(m, bm)
    bn = common.pick_block(n, bn)
    bk = common.pick_block(k, bk)
    mp, np_, kp = (common.round_up(m, bm), common.round_up(n, bn),
                   common.round_up(k, bk))
    xp = common.pad_axis(common.pad_axis(x, 0, mp, 0), 1, kp, 0)
    yp = common.pad_axis(common.pad_axis(y, 0, kp, 0), 1, np_, 0)

    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_mm_int8_kernel, nsteps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def vmem_report(m: int, n: int, k: int, bm: int = 64, bn: int = 128,
                bk: int = 128) -> dict:
    """Static VMEM/MXU estimate for a matmul tiling (recorded by aot.py)."""
    bm = common.pick_block(m, bm)
    bn = common.pick_block(n, bn)
    bk = common.pick_block(k, bk)
    vmem = common.block_vmem_bytes((bm, bk), (bk, bn), (bm, bn))
    flops = 2 * m * n * k
    # MXU utilization estimate: fraction of the 128x128 systolic array the
    # block actually covers, times the fraction of the padded grid that is
    # real work.
    mxu_cover = min(bn, 128) * min(bk, 128) / (128 * 128)
    real = (m * n * k) / (
        common.round_up(m, bm) * common.round_up(n, bn) * common.round_up(k, bk)
    )
    return {
        "block": [bm, bn, bk],
        "vmem_bytes": vmem,
        "vmem_ok": vmem <= common.VMEM_BUDGET_BYTES,
        "flops": flops,
        "mxu_utilization_est": round(mxu_cover * real, 4),
    }
