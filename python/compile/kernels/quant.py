"""Affine int8 quantize / dequantize Pallas kernels.

The Edge TPU executes int8 models exclusively; the NCS2 favours fp16 but
gains from int8 as well.  These kernels implement the standard affine scheme
``q = clamp(round(x / scale) + zero_point, -128, 127)`` used by the quantized
model variants and the quantization ablation bench.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common


def _quant_kernel(x_ref, s_ref, o_ref):
    scale = s_ref[0, 0]
    zp = s_ref[0, 1]
    q = jnp.round(x_ref[...] / scale) + zp
    o_ref[...] = jnp.clip(q, -128.0, 127.0).astype(jnp.int8)


def quantize(x, scale: float, zero_point: int = 0, bn: int = 4096):
    """x: (N,) f32 -> (N,) int8 under the affine scheme."""
    (n,) = x.shape
    bn = common.pick_block(n, bn)
    np_ = common.round_up(n, bn)
    xp = common.pad_axis(x, 0, np_).reshape(np_ // bn, bn)
    params = jnp.array([[float(scale), float(zero_point)]], jnp.float32)

    out = pl.pallas_call(
        _quant_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((1, bn), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_ // bn, bn), jnp.int8),
        interpret=True,
    )(xp, params)
    return out.reshape(np_)[:n]


def _dequant_kernel(q_ref, s_ref, o_ref):
    scale = s_ref[0, 0]
    zp = s_ref[0, 1]
    o_ref[...] = (q_ref[...].astype(jnp.float32) - zp) * scale


def dequantize(q, scale: float, zero_point: int = 0, bn: int = 4096):
    """q: (N,) int8 -> (N,) f32."""
    (n,) = q.shape
    bn = common.pick_block(n, bn)
    np_ = common.round_up(n, bn)
    qp = common.pad_axis(q, 0, np_, 0).reshape(np_ // bn, bn)
    params = jnp.array([[float(scale), float(zero_point)]], jnp.float32)

    out = pl.pallas_call(
        _dequant_kernel,
        grid=(np_ // bn,),
        in_specs=[
            pl.BlockSpec((1, bn), lambda i: (i, 0)),
            pl.BlockSpec((1, 2), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_ // bn, bn), jnp.float32),
        interpret=True,
    )(qp, params)
    return out.reshape(np_)[:n]


def calibrate_scale(x, percentile: float = 99.9) -> float:
    """Symmetric per-tensor calibration: scale so that the given percentile
    of |x| maps to 127."""
    amax = jnp.percentile(jnp.abs(x), percentile)
    return float(jnp.maximum(amax, 1e-6) / 127.0)
