"""Shared helpers for the CHAMP Pallas kernels.

All kernels in this package are lowered with ``interpret=True``: the image's
PJRT plugin is CPU-only and real TPU lowering would emit Mosaic custom-calls
it cannot execute.  The BlockSpec structure is still written as if targeting
a VMEM-limited accelerator (the NCS2's 2.5 MB CMX scratchpad is the budget we
tile for -- see DESIGN.md section "Hardware adaptation").
"""

from __future__ import annotations

import jax.numpy as jnp

# VMEM budget we tile for, in bytes.  The Movidius Myriad X has 2.5 MB of CMX
# scratchpad; the Edge TPU has 8 MB of on-chip SRAM.  We tile for the smaller.
VMEM_BUDGET_BYTES = 2_500_000

# MXU-friendly inner dimension: blocks are multiples of 128 lanes wherever the
# operand is large enough to support it.
LANE = 128


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return ((x + m - 1) // m) * m


def pad_axis(x, axis: int, target: int, value=0.0):
    """Zero-pad ``x`` along ``axis`` up to length ``target``."""
    cur = x.shape[axis]
    if cur == target:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - cur)
    return jnp.pad(x, pad, constant_values=value)


def pick_block(dim: int, preferred: int) -> int:
    """Pick a block size for a dimension: ``preferred`` when the dimension is
    at least that large, otherwise the whole (rounded-up-to-8) dimension."""
    if dim >= preferred:
        return preferred
    return max(8, round_up(dim, 8))


def block_vmem_bytes(*block_shapes, dtype_bytes: int = 4) -> int:
    """Total VMEM footprint of a set of resident blocks (double-buffered)."""
    total = 0
    for shape in block_shapes:
        n = 1
        for d in shape:
            n *= d
        total += n * dtype_bytes
    # Double buffering: the HBM->VMEM pipeline keeps two copies in flight.
    return 2 * total
