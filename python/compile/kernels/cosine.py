"""Cosine-similarity gallery match Pallas kernel.

The biometric matching hot spot: probe embeddings against a (possibly large)
gallery.  The gallery is streamed through VMEM in blocks of ``bg`` templates
while the (small) probe block stays resident; this is exactly the
HBM->VMEM schedule the storage cartridge's DMA engine would run when the
gallery lives on the module's flash.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import common

EPS = 1e-8


def _cos_kernel(p_ref, g_ref, o_ref):
    p = p_ref[...]
    g = g_ref[...]
    pn = p * jax.lax.rsqrt(jnp.sum(p * p, axis=-1, keepdims=True) + EPS)
    gn = g * jax.lax.rsqrt(jnp.sum(g * g, axis=-1, keepdims=True) + EPS)
    o_ref[...] = jnp.dot(pn, gn.T, preferred_element_type=jnp.float32)


def cosine_scores(probe, gallery, bg: int = 256):
    """Cosine similarity of every probe row against every gallery row.

    probe: (B, D) f32, gallery: (G, D) f32 -> (B, G) f32 in [-1, 1].
    Zero rows map to score ~0 (EPS-regularized norms).
    """
    b, d = probe.shape
    g, d2 = gallery.shape
    assert d == d2
    bg = common.pick_block(g, bg)
    gp = common.round_up(g, bg)
    gal = common.pad_axis(gallery, 0, gp)

    grid = (gp // bg,)
    out = pl.pallas_call(
        _cos_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((bg, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, bg), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, gp), jnp.float32),
        interpret=True,
    )(probe, gal)
    return out[:, :g]


def _rot_kernel(p_ref, r_ref, g_ref, o_ref):
    # Rotate the probe into the protected space, then match.  The gallery is
    # already stored rotated on the cartridge, so plaintext templates never
    # appear on the bus.
    p = jnp.dot(p_ref[...], r_ref[...], preferred_element_type=jnp.float32)
    g = g_ref[...]
    pn = p * jax.lax.rsqrt(jnp.sum(p * p, axis=-1, keepdims=True) + EPS)
    gn = g * jax.lax.rsqrt(jnp.sum(g * g, axis=-1, keepdims=True) + EPS)
    o_ref[...] = jnp.dot(pn, gn.T, preferred_element_type=jnp.float32)


def secure_scores(probe, rotation, gallery_rot, bg: int = 256):
    """Match in the orthogonally-rotated (template-protected) space.

    probe: (B, D) plaintext embeddings; rotation: (D, D) orthogonal secret;
    gallery_rot: (G, D) pre-rotated gallery.  Because rotation preserves
    inner products, the scores equal plaintext cosine scores -- the property
    the tests assert.
    """
    b, d = probe.shape
    g, _ = gallery_rot.shape
    bg = common.pick_block(g, bg)
    gp = common.round_up(g, bg)
    gal = common.pad_axis(gallery_rot, 0, gp)

    grid = (gp // bg,)
    out = pl.pallas_call(
        _rot_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((d, d), lambda i: (0, 0)),
            pl.BlockSpec((bg, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, bg), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, gp), jnp.float32),
        interpret=True,
    )(probe, rotation, gal)
    return out[:, :g]


def vmem_report(b: int, g: int, d: int, bg: int = 256) -> dict:
    bg = common.pick_block(g, bg)
    vmem = common.block_vmem_bytes((b, d), (bg, d), (b, bg))
    return {
        "block": [b, bg, d],
        "vmem_bytes": vmem,
        "vmem_ok": vmem <= common.VMEM_BUDGET_BYTES,
        "flops": 2 * b * g * d,
    }
