"""CHAMP Layer-1 Pallas kernels (build-time only; interpret=True on CPU)."""
from . import common, cosine, dwconv, matmul, quant, ref  # noqa: F401
