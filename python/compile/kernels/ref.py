"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest sweeps shapes/dtypes with
hypothesis and asserts the Pallas kernels match these to tight tolerances.
No pallas imports allowed in this file.
"""

from __future__ import annotations

import jax.numpy as jnp

EPS = 1e-8


def matmul_bias(x, y, b, activation: str = "none"):
    out = x.astype(jnp.float32) @ y.astype(jnp.float32) + b
    if activation == "relu6":
        out = jnp.clip(out, 0.0, 6.0)
    elif activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out


def matmul_int8(x, y):
    return x.astype(jnp.int32) @ y.astype(jnp.int32)


def depthwise3x3(x, w, b, relu6: bool = True):
    h, wd, c = x.shape
    xp = jnp.pad(x, ((1, 1), (1, 1), (0, 0)))
    acc = jnp.zeros((h, wd, c), jnp.float32)
    for dy in range(3):
        for dx in range(3):
            acc = acc + xp[dy:dy + h, dx:dx + wd, :] * w[dy, dx, :]
    acc = acc + b
    if relu6:
        acc = jnp.clip(acc, 0.0, 6.0)
    return acc


def _l2n(v):
    return v / jnp.sqrt(jnp.sum(v * v, axis=-1, keepdims=True) + EPS)


def cosine_scores(probe, gallery):
    return _l2n(probe) @ _l2n(gallery).T


def secure_scores(probe, rotation, gallery_rot):
    return _l2n(probe @ rotation) @ _l2n(gallery_rot).T


def quantize(x, scale, zero_point=0):
    q = jnp.round(x / scale) + zero_point
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def dequantize(q, scale, zero_point=0):
    return (q.astype(jnp.float32) - zero_point) * scale
