"""CHAMP Layer-2: the cartridge model zoo, written in JAX.

Each capability cartridge in the paper runs one network.  The zoo below
mirrors the paper's cartridge list (section 3.2) with compile-time-friendly
"lite" variants: same architecture family and output contract, scaled to the
96x96/64x64 inputs that a Myriad-X-class accelerator actually serves after
the host's ROI crop.

All pointwise (1x1) convolutions and FC layers route through the Layer-1
Pallas ``matmul_bias`` kernel; stride-1 depthwise 3x3 convs route through the
Pallas ``depthwise3x3`` kernel; strided convolutions use ``lax`` directly
(they are <10% of FLOPs and stride is awkward under a stencil BlockSpec --
see DESIGN.md).  Weights are deterministic (seeded) and baked into the HLO as
constants, so the AOT artifacts are self-contained: the Rust runtime feeds
frames, nothing else.

Build-time only.  Never imported on the request path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import cosine as kcos
from .kernels import dwconv as kdw
from .kernels import matmul as kmm
from .kernels import quant as kq

# ---------------------------------------------------------------------------
# Parameter factory: deterministic He-style init, one PRNG stream per model.
# ---------------------------------------------------------------------------


class Params:
    """Deterministic parameter factory; counts params for the manifest."""

    def __init__(self, seed: int):
        self._key = jax.random.PRNGKey(seed)
        self.n_params = 0

    def take(self, shape, fan_in=None):
        self._key, sub = jax.random.split(self._key)
        fan = fan_in if fan_in is not None else (shape[0] if shape else 1)
        w = jax.random.normal(sub, shape) * jnp.sqrt(2.0 / max(fan, 1))
        n = 1
        for d in shape:
            n *= d
        self.n_params += n
        return w

    def zeros(self, shape):
        n = 1
        for d in shape:
            n *= d
        self.n_params += n
        return jnp.zeros(shape, jnp.float32)


# ---------------------------------------------------------------------------
# Layer helpers (single image, HWC layout).
# ---------------------------------------------------------------------------


def conv2d(x, w, b, stride=1):
    """General conv via lax (used only for strided/spatial stem layers).
    x: (H,W,Cin), w: (kh,kw,Cin,Cout)."""
    out = lax.conv_general_dilated(
        x[None], w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))[0]
    return out + b


def pointwise(x, w, b, activation="relu6"):
    """1x1 conv as a Pallas GEMM.  x: (H,W,Cin), w: (Cin,Cout).

    bm=576 (vs the 64 default): one grid step covers a 24x24 feature map
    and 48x48 maps take 4 steps.  Fewer grid iterations cut interpret-mode
    dispatch overhead ~2x (EXPERIMENTS.md SPerf iter. 3) while the
    double-buffered working set stays ~1.3 MB < the 2.5 MB CMX budget."""
    h, wd, cin = x.shape
    out = kmm.matmul_bias(x.reshape(h * wd, cin), w, b, activation, bm=576)
    return out.reshape(h, wd, -1)


def pointwise_int8(x, w, b, activation="relu6", x_scale=0.05, w_scale=0.01):
    """Quantized 1x1 conv: int8 GEMM with affine (de)quant Pallas kernels.

    Mirrors the Edge TPU execution path; accumulation in int32, rescale to
    f32 afterwards.  Scales are static (calibrated offline).
    """
    h, wd, cin = x.shape
    cout = w.shape[1]
    xq = kq.quantize(x.reshape(-1), x_scale).reshape(h * wd, cin)
    wq = kq.quantize(w.reshape(-1), w_scale).reshape(cin, cout)
    acc = kmm.matmul_int8(xq, wq)
    out = acc.astype(jnp.float32) * (x_scale * w_scale) + b
    if activation == "relu6":
        out = jnp.clip(out, 0.0, 6.0)
    elif activation == "relu":
        out = jnp.maximum(out, 0.0)
    return out.reshape(h, wd, cout)


def depthwise(x, w, b, stride=1, relu6=True):
    """Depthwise 3x3.  Stride-1 goes through the Pallas stencil kernel;
    stride-2 subsamples the stride-1 output (identical numerics; the extra
    work is negligible at these compile-scale resolutions)."""
    out = kdw.depthwise3x3(x, w, b, relu6)
    if stride == 2:
        out = out[::2, ::2, :]
    return out


def inverted_residual(x, p, cin, cout, expand, stride, int8=False):
    """MobileNetV2 inverted-residual block (expand -> depthwise -> project)."""
    cmid = cin * expand
    pw = pointwise_int8 if int8 else pointwise
    h = pw(x, p.take((cin, cmid)), p.zeros((cmid,)), "relu6")
    h = depthwise(h, p.take((3, 3, cmid), fan_in=9), p.zeros((cmid,)), stride)
    h = pw(h, p.take((cmid, cout)), p.zeros((cout,)), "none")
    if stride == 1 and cin == cout:
        h = h + x
    return h


def global_avg_pool(x):
    return jnp.mean(x, axis=(0, 1))


# ---------------------------------------------------------------------------
# Cartridge models.  Each returns a tuple of outputs (AOT lowers with
# return_tuple=True; the Rust side unwraps the tuple).
# ---------------------------------------------------------------------------

NUM_CLASSES = 21          # VOC-style: 20 classes + background
DET_ANCHORS = 2
EMBED_DIM = 128
GAIT_DIM = 64
GALLERY_SIZE = 1024
GAIT_FRAMES = 8


def _mnv2_backbone(x, p, int8=False):
    """Shared MobileNetV2-lite backbone: 96x96x3 -> 6x6x96."""
    h = jnp.clip(conv2d(x, p.take((3, 3, 3, 16), fan_in=27), p.zeros((16,)), 2),
                 0.0, 6.0)                                    # 48x48x16
    h = inverted_residual(h, p, 16, 16, 1, 1, int8)
    h = inverted_residual(h, p, 16, 24, 2, 2, int8)           # 24x24x24
    h = inverted_residual(h, p, 24, 24, 2, 1, int8)
    h = inverted_residual(h, p, 24, 48, 2, 2, int8)           # 12x12x48
    h = inverted_residual(h, p, 48, 48, 2, 1, int8)
    h = inverted_residual(h, p, 48, 96, 2, 2, int8)           # 6x6x96
    return h


def mobilenet_v2_det(x, int8=False):
    """Object-detection cartridge: MobileNetV2-lite + SSD-lite head.

    x: (96, 96, 3) f32 in [0,1].
    Returns (boxes (72, 4) cxcywh in [0,1], logits (72, 21)).
    72 = 6*6 cells * 2 anchors.
    """
    p = Params(seed=101)
    x = x * 2.0 - 1.0
    h = _mnv2_backbone(x, p, int8)
    pw = pointwise_int8 if int8 else pointwise
    head = pw(h, p.take((96, 128)), p.zeros((128,)), "relu6")   # 6x6x128
    raw = pw(head, p.take((128, DET_ANCHORS * (4 + NUM_CLASSES))),
             p.zeros((DET_ANCHORS * (4 + NUM_CLASSES),)), "none")
    raw = raw.reshape(6 * 6 * DET_ANCHORS, 4 + NUM_CLASSES)
    boxes = jax.nn.sigmoid(raw[:, :4])
    logits = raw[:, 4:]
    return boxes, logits


def retinaface_det(x):
    """Face-detection cartridge (RetinaFace-lite, single FPN level).

    x: (96, 96, 3) f32.  Returns (scores (36,), boxes (36, 4),
    landmarks (36, 10)) over a 6x6 grid, 1 anchor per cell.
    """
    p = Params(seed=202)
    x = x * 2.0 - 1.0
    h = _mnv2_backbone(x, p)
    ctx = pointwise(h, p.take((96, 64)), p.zeros((64,)), "relu")   # SSH-lite
    ctx = depthwise(ctx, p.take((3, 3, 64), fan_in=9), p.zeros((64,)), 1)
    raw = pointwise(ctx, p.take((64, 15)), p.zeros((15,)), "none")
    raw = raw.reshape(36, 15)
    return raw[:, 0], jax.nn.sigmoid(raw[:, 1:5]), raw[:, 5:]


def facenet_embed(x):
    """Face-recognition cartridge (FaceNet-lite).

    x: (64, 64, 3) f32 aligned face crop.
    Returns (embedding (128,),) L2-normalized -- cosine-space templates.
    """
    p = Params(seed=303)
    x = x * 2.0 - 1.0
    h = jnp.clip(conv2d(x, p.take((3, 3, 3, 24), fan_in=27), p.zeros((24,)), 2),
                 0.0, 6.0)                                    # 32x32x24
    h = inverted_residual(h, p, 24, 32, 2, 2)                 # 16x16x32
    h = inverted_residual(h, p, 32, 32, 2, 1)
    h = inverted_residual(h, p, 32, 64, 2, 2)                 # 8x8x64
    h = inverted_residual(h, p, 64, 64, 2, 1)
    h = inverted_residual(h, p, 64, 128, 2, 2)                # 4x4x128
    flat = h.reshape(1, 4 * 4 * 128)
    # bk=1024: the 2048-deep FC runs in 2 K-steps instead of 16 (SPerf).
    emb = kmm.matmul_bias(flat, p.take((4 * 4 * 128, EMBED_DIM)),
                          p.zeros((EMBED_DIM,)), "none", bk=1024)[0]
    emb = emb / jnp.sqrt(jnp.sum(emb * emb) + 1e-8)
    return (emb,)


def crfiqa_quality(x):
    """Face-quality cartridge (CR-FIQA-lite): quality in [0, 1].

    x: (64, 64, 3) f32 face crop.  Returns (quality (1,),).
    """
    p = Params(seed=404)
    x = x * 2.0 - 1.0
    h = jnp.clip(conv2d(x, p.take((3, 3, 3, 16), fan_in=27), p.zeros((16,)), 2),
                 0.0, 6.0)                                    # 32x32x16
    h = inverted_residual(h, p, 16, 24, 2, 2)                 # 16x16x24
    h = inverted_residual(h, p, 24, 48, 2, 2)                 # 8x8x48
    feat = global_avg_pool(h).reshape(1, 48)
    q = kmm.matmul_bias(feat, p.take((48, 1)), p.zeros((1,)), "none")
    return (jax.nn.sigmoid(q[0]),)


def gaitset_embed(sils):
    """Gait-recognition cartridge (GaitSet-lite): set-pooled silhouettes.

    sils: (8, 32, 32) f32 binary-ish silhouettes.
    Returns (embedding (64,),) L2-normalized.
    """
    p = Params(seed=505)
    cw1 = p.take((3, 3, 1, 16), fan_in=9)
    cb1 = p.zeros((16,))
    cw2 = p.take((3, 3, 16, 32), fan_in=144)
    cb2 = p.zeros((32,))

    def frame_feat(f):
        h = jnp.maximum(conv2d(f[:, :, None], cw1, cb1, 2), 0.0)   # 16x16x16
        h = jnp.maximum(conv2d(h, cw2, cb2, 2), 0.0)               # 8x8x32
        return h

    feats = jax.vmap(frame_feat)(sils)          # (8, 8, 8, 32)
    setf = jnp.max(feats, axis=0)               # set pooling (GaitSet's core op)
    flat = setf.reshape(1, 8 * 8 * 32)
    emb = kmm.matmul_bias(flat, p.take((8 * 8 * 32, GAIT_DIM)),
                          p.zeros((GAIT_DIM,)), "none", bk=1024)[0]
    emb = emb / jnp.sqrt(jnp.sum(emb * emb) + 1e-8)
    return (emb,)


def gallery_match(probe, gallery):
    """Database-cartridge plaintext matcher.

    probe: (1, 128), gallery: (G, 128).
    Returns (scores (1, G), best_idx (1,) i32, best_score (1,)).
    """
    scores = kcos.cosine_scores(probe, gallery)
    best = jnp.argmax(scores, axis=1).astype(jnp.int32)
    return scores, best, jnp.max(scores, axis=1)


def secure_gallery_match(probe, rotation, gallery_rot):
    """Database-cartridge protected matcher: gallery stored rotated; the
    probe is rotated inside the kernel; scores equal plaintext cosine."""
    scores = kcos.secure_scores(probe, rotation, gallery_rot)
    best = jnp.argmax(scores, axis=1).astype(jnp.int32)
    return scores, best, jnp.max(scores, axis=1)


# ---------------------------------------------------------------------------
# AOT registry: name -> (fn, example input ShapeDtypeStructs, description).
# ---------------------------------------------------------------------------

F32 = jnp.float32


def _s(shape, dtype=F32):
    return jax.ShapeDtypeStruct(shape, dtype)


REGISTRY = {
    "mobilenet_v2_det": (
        lambda x: mobilenet_v2_det(x, int8=False),
        [_s((96, 96, 3))],
        "Object-detection cartridge: MobileNetV2-lite + SSD-lite head",
    ),
    "mobilenet_v2_det_int8": (
        lambda x: mobilenet_v2_det(x, int8=True),
        [_s((96, 96, 3))],
        "Quantized (int8 GEMM) variant of the detection cartridge",
    ),
    "retinaface_det": (
        retinaface_det,
        [_s((96, 96, 3))],
        "Face-detection cartridge: RetinaFace-lite",
    ),
    "facenet_embed": (
        facenet_embed,
        [_s((64, 64, 3))],
        "Face-recognition cartridge: FaceNet-lite 128-d embeddings",
    ),
    "crfiqa_quality": (
        crfiqa_quality,
        [_s((64, 64, 3))],
        "Face-quality cartridge: CR-FIQA-lite",
    ),
    "gaitset_embed": (
        gaitset_embed,
        [_s((GAIT_FRAMES, 32, 32))],
        "Gait-recognition cartridge: GaitSet-lite 64-d embeddings",
    ),
    "gallery_match": (
        gallery_match,
        [_s((1, EMBED_DIM)), _s((GALLERY_SIZE, EMBED_DIM))],
        "Database cartridge: plaintext cosine gallery match",
    ),
    "secure_gallery_match": (
        secure_gallery_match,
        [_s((1, EMBED_DIM)), _s((EMBED_DIM, EMBED_DIM)),
         _s((GALLERY_SIZE, EMBED_DIM))],
        "Database cartridge: rotation-protected gallery match",
    ),
}
