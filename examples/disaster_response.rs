//! Disaster-response scenario (paper §5): a drone feed analyzed at the
//! edge; the operator reflashes the FPGA cartridge from debris detection
//! (object-detect bitstream) to person detection mid-mission.
//!
//!     cargo run --release --example disaster_response

use champ::bus::topology::SlotId;
use champ::bus::usb3::BusProfile;
use champ::coordinator::scheduler::Orchestrator;
use champ::device::caps::CapDescriptor;
use champ::device::fpga::{reflash, Bitstream};
use champ::device::{Cartridge, DeviceKind};
use champ::workload::video::VideoSource;

fn main() -> anyhow::Result<()> {
    // Phase 1: debris survey with an object-detection bitstream.
    let mut o = Orchestrator::new(BusProfile::usb3_gen1(), 4);
    let uid =
        o.plug(SlotId(0), Cartridge::new(0, DeviceKind::Fpga, CapDescriptor::object_detect()))?;
    let mut drone = VideoSource::paper_stream(21).with_rate_fps(10.0);
    let rep1 = o.run_pipelined(&mut drone, 50, vec![]);
    println!("phase 1 (debris survey): {:.1} fps, mean latency {:.1} ms",
        rep1.fps, rep1.latency.mean_us() / 1e3);

    // Phase 2: survivors suspected — reflash to face detection.
    let bus_rate = o.bus.profile.bytes_per_us();
    let cart = o.carts.get_mut(&uid).unwrap();
    let reflash_us = reflash(cart, Bitstream::for_cap(CapDescriptor::face_detect()), bus_rate)?;
    println!("reflash to face-detect: {:.2} s (bitstream push + partial reconfiguration)",
        reflash_us as f64 / 1e6);
    // Registry must re-learn the capability (new handshake after DPR).
    let slot = o.topology.slot_of(uid).unwrap();
    o.unplug(slot)?;
    let c2 = {
        let mut c = Cartridge::new(uid, DeviceKind::Fpga, CapDescriptor::face_detect());
        c.uid = uid;
        c
    };
    o.plug(slot, c2)?;
    o.clock.advance_by(reflash_us);

    let rep2 = o.run_pipelined(&mut drone, 50, vec![]);
    println!("phase 2 (person search): {:.1} fps, mean latency {:.1} ms",
        rep2.fps, rep2.latency.mean_us() / 1e3);
    println!("pipeline now: {}",
        o.pipeline.stages.iter().map(|s| s.cap.id.name()).collect::<Vec<_>>().join(" -> "));
    assert_eq!(o.pipeline.stages[0].cap.id.name(), "face-detect");
    Ok(())
}
