//! Quickstart: assemble a CHAMP unit, run a face pipeline, export the
//! operator workflow graph.
//!
//!     cargo run --release --example quickstart [-- --export-workflow]
//!
//! Uses the simulated timing backend only (no artifacts needed), so this is
//! the fastest way to see the system move.

use champ::bus::topology::SlotId;
use champ::bus::usb3::BusProfile;
use champ::coordinator::scheduler::Orchestrator;
use champ::coordinator::ui;
use champ::device::caps::CapDescriptor;
use champ::device::{Cartridge, DeviceKind};
use champ::workload::video::VideoSource;

fn main() -> anyhow::Result<()> {
    // 1. A CHAMP unit: USB3 bus, six slots.
    let mut champ = Orchestrator::new(BusProfile::usb3_gen1(), 6);

    // 2. The operator plugs cartridges in pipeline order (the system
    //    auto-configures from physical slot order — paper §3.3).
    champ.plug(SlotId(0), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_detect()))?;
    champ.plug(SlotId(1), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_quality()))?;
    champ.plug(SlotId(2), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_embed()))?;
    println!("pipeline: {}",
        champ.pipeline.stages.iter().map(|s| s.cap.id.name()).collect::<Vec<_>>().join(" -> "));

    // 3. Drive a camera stream through it.
    let mut camera = VideoSource::paper_stream(42).with_rate_fps(8.0);
    let report = champ.run_pipelined(&mut camera, 100, vec![]);
    println!("frames : {} in, {} out, {} dropped",
        report.frames_in, report.frames_out, report.frames_dropped);
    println!("fps    : {:.2}", report.fps);
    println!("latency: mean {:.1} ms  p99 {:.1} ms  (pure compute {:.1} ms, overhead {:.1}%)",
        report.latency.mean_us() / 1e3,
        report.latency.percentile_us(99.0) as f64 / 1e3,
        report.compute_us_mean / 1e3,
        (report.latency.mean_us() / report.compute_us_mean - 1.0) * 100.0);

    // 4. Export the ComfyUI-style operator view (paper Fig. 3).
    if std::env::args().any(|a| a == "--export-workflow") {
        println!("{}", ui::export_workflow(&champ.pipeline, "quickstart").to_json_pretty());
    }
    Ok(())
}
