//! Hot-swap demo: the paper's §4.2 experiment as an operator story.
//!
//!     cargo run --release --example hotswap_demo
//!
//! A 3-stage face pipeline runs at 8 FPS; the operator yanks the quality
//! cartridge mid-mission (VDiSK bridges it out in ~0.5 s, buffering frames),
//! then re-inserts it (~2 s to reload the model).  No frames are lost.

use champ::bus::topology::SlotId;
use champ::bus::usb3::BusProfile;
use champ::coordinator::scheduler::Orchestrator;
use champ::device::caps::CapDescriptor;
use champ::device::{Cartridge, DeviceKind};
use champ::workload::traces::MissionTrace;
use champ::workload::video::VideoSource;

fn main() -> anyhow::Result<()> {
    let mut o = Orchestrator::new(BusProfile::usb3_gen1(), 6);
    o.plug(SlotId(0), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_detect()))?;
    let quality =
        o.plug(SlotId(1), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_quality()))?;
    o.plug(SlotId(2), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_embed()))?;

    println!("T+0.0s  pipeline up: face-detect -> face-quality -> face-embed");
    println!("T+5.0s  operator pulls the quality cartridge (slot 1)");
    println!("T+10.0s operator re-inserts it\n");

    let trace = MissionTrace::hotswap_experiment();
    let events = trace.to_hotplug_events(quality);
    let fps = 8.0;
    let frames = (trace.total_run_us() as f64 / 1e6 * fps) as u64;
    let mut cam = VideoSource::paper_stream(11).with_rate_fps(fps);
    let rep = o.run_pipelined(&mut cam, frames, events);

    for r in &rep.swap_records {
        println!("event {:?} at slot {} seen T+{:.2}s -> pipeline resumed T+{:.2}s \
(downtime {:.2}s, {:?})",
            r.kind, r.slot.0,
            r.visible_us as f64 / 1e6, r.resumed_us as f64 / 1e6,
            r.downtime_us() as f64 / 1e6, r.action);
    }
    println!("\nframes: {} in / {} out / {} dropped (buffered peak {})",
        rep.frames_in, rep.frames_out, rep.frames_dropped, rep.max_buffered);
    println!("fps over the whole mission: {:.2} (source {fps})", rep.fps);
    assert_eq!(rep.frames_dropped, 0, "the §4.2 guarantee: buffer, never drop");
    println!("final pipeline: {}",
        o.pipeline.stages.iter().map(|s| s.cap.id.name()).collect::<Vec<_>>().join(" -> "));
    Ok(())
}
