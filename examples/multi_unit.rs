//! Multi-unit CHAMP: a federated rack of units serving one gallery
//! (paper §3.1 scaled out).
//!
//!     cargo run --release --example multi_unit
//!
//! Three units share a 3 000-identity corpus under rendezvous placement
//! with replication factor 2.  Identify probes scatter to every unit
//! holding routed keys, each unit scans its shard in parallel, and the
//! per-unit top-k lists heap-merge into an answer bit-identical to a
//! single-unit scan over the whole corpus.  The demo then pulls a unit
//! mid-flight (the replicas absorb it), brings it back, and racks a
//! fourth unit whose shard fills through incremental rebalance steps.

use champ::biometric::index::GalleryIndex;
use champ::serve::federation::FederationRouter;
use champ::util::rng::Rng;

const DIM: usize = 32;
const CORPUS: usize = 3_000;
const K: usize = 5;

fn print_hits(label: &str, router: &FederationRouter, hits: &[(u32, f32)]) {
    let top = hits
        .iter()
        .map(|&(seq, score)| format!("{}:{score:.4}", router.id_of(seq)))
        .collect::<Vec<_>>()
        .join("  ");
    println!("{label:<28} {top}");
}

fn main() -> anyhow::Result<()> {
    // Rack of three units, every identity on two of them.
    let uids: Vec<u64> = (0..3).map(|i| 0xFED0_0000 + i).collect();
    let mut router = FederationRouter::new(DIM, &uids, 2);

    // Enroll the corpus; keep a flat single-unit index as the oracle.
    let mut oracle = GalleryIndex::new(DIM);
    let mut rng = Rng::new(0x05ca77e4);
    for i in 0..CORPUS {
        let id = format!("person-{i:04}");
        let t = rng.unit_vec(DIM);
        router.enroll(&id, &t)?;
        oracle.upsert(id, &t);
    }
    println!(
        "{} identities over {} units (RF {}), shard sizes: {:?}",
        router.enrolled_count(),
        router.unit_count(),
        router.replication(),
        (0..router.unit_count()).map(|u| router.assigned_count(u)).collect::<Vec<_>>()
    );

    // A probe: a noisy view of an enrolled face.
    let probe: Vec<f32> = {
        let mut noise = Rng::new(42);
        router
            .template_of(1_234)
            .iter()
            .map(|&x| x + 0.05 * noise.normal())
            .collect()
    };

    // Scatter-gather identify vs the covering single-unit scan: the
    // merged answer must be bit-identical (same scores, same order).
    let fed = router.identify(&probe, K);
    let flat = oracle.top_k(&probe, K);
    assert_eq!(fed.len(), flat.len());
    for (&(seq, fs), &(row, os)) in fed.iter().zip(flat.iter()) {
        assert_eq!(router.id_of(seq), oracle.id_of(row), "merge order must match the flat scan");
        assert_eq!(fs.to_bits(), os.to_bits(), "scores must be bit-identical");
    }
    print_hits("federated top-k:", &router, &fed);
    println!("(bit-identical to a single-unit scan over the union)");

    // Pull unit 0: every key it served re-routes to its replica, and the
    // answer does not change by a single bit.
    router.detach(0);
    let pulled = router.identify(&probe, K);
    assert_eq!(pulled, fed, "RF 2 must absorb a single unit loss");
    print_hits("after detaching unit 0:", &router, &pulled);
    router.reattach(0);

    // Rack a fourth unit: placement re-ranks and the new shard fills via
    // bounded rebalance steps, exactly-once accounted.
    let unit = router.attach_expand(0xFED0_0003, None, None)?;
    let total = router.rebalance_pending();
    let mut steps = 0;
    while router.rebalance_pending() > 0 {
        router.rebalance_step(64, steps * 1_000)?;
        steps += 1;
        assert!(router.rebalance_accounting_holds(), "every transfer accounted exactly once");
    }
    println!(
        "racked unit {unit}: {total} copies drained in {steps} steps, shard sizes now {:?}",
        (0..router.unit_count()).map(|u| router.assigned_count(u)).collect::<Vec<_>>()
    );

    let expanded = router.identify(&probe, K);
    assert_eq!(expanded, fed, "rebalance must not change any answer");
    print_hits("after racking unit 3:", &router, &expanded);
    println!("scatter-gather pass cost: {} us (virtual)", router.fed_pass_us(1, K));
    Ok(())
}
