//! Multi-unit CHAMP: two units chained over Gigabit Ethernet (paper §3.1).
//!
//!     cargo run --release --example multi_unit
//!
//! Unit A (vehicle checkpoint) runs detect + quality; unit B (command
//! post) runs the embedder.  Intermediate face crops cross the GbE link.

use champ::bus::topology::SlotId;
use champ::bus::usb3::BusProfile;
use champ::coordinator::link::UnitLink;
use champ::coordinator::pipeline::{Pipeline, Stage};
use champ::coordinator::scheduler::Orchestrator;
use champ::device::caps::CapDescriptor;
use champ::device::{Cartridge, DeviceKind};
use champ::workload::video::VideoSource;

fn main() -> anyhow::Result<()> {
    // Unit A: head of the pipeline.
    let mut a = Orchestrator::new(BusProfile::usb3_gen1(), 4);
    a.plug(SlotId(0), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_detect()))?;
    a.plug(SlotId(1), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_quality()))?;

    // Unit B: the tail (embedder).  Its head consumes FaceCrop, which is
    // not camera-runnable on its own — exactly why it lives behind a link.
    let mut b = Orchestrator::new(BusProfile::usb3_gen1(), 4);
    let cart = Cartridge::new(1, DeviceKind::Ncs2, CapDescriptor::face_embed());
    b.topology.insert(SlotId(0), 1)?;
    b.registry.register(1, SlotId(0), cart.cap.clone(), 0);
    b.pipeline = Pipeline { stages: vec![Stage { uid: 1, cap: cart.cap.clone() }] };
    b.carts.insert(1, cart);

    let mut link = UnitLink::gbe();
    let mut cam = VideoSource::paper_stream(3).with_rate_fps(6.0);
    let rep = link.run_split(&mut a, &mut b, &mut cam, 60)?;

    println!("unit A: {} | link: GbE | unit B: {}",
        a.pipeline.stages.iter().map(|s| s.cap.id.name()).collect::<Vec<_>>().join(" -> "),
        b.pipeline.stages.iter().map(|s| s.cap.id.name()).collect::<Vec<_>>().join(" -> "));
    println!("frames: {}  fps: {:.2}", rep.frames, rep.fps);
    println!("e2e latency: mean {:.1} ms (link crossings total {:.1} ms)",
        rep.latency.mean_us() / 1e3, rep.link_us_total as f64 / 1e3);
    Ok(())
}
