//! Table-1 scaling sweep through the public API (paper §4.1).
//!
//!     cargo run --release --example scaling_sweep [-- --kind coral]

use champ::bus::topology::SlotId;
use champ::bus::usb3::BusProfile;
use champ::coordinator::scheduler::Orchestrator;
use champ::device::caps::CapDescriptor;
use champ::device::{Cartridge, DeviceKind};
use champ::workload::video::VideoSource;

fn main() -> anyhow::Result<()> {
    let kind = if std::env::args().any(|a| a == "coral") {
        DeviceKind::Coral
    } else {
        DeviceKind::Ncs2
    };
    println!("broadcast scaling, {kind:?}, MobileNetV2 300x300, saturating stream");
    println!("{:<10} {:>8} {:>12} {:>12} {:>14}", "devices", "FPS", "wire util", "host util", "per-dev FPS");
    for n in 1..=5usize {
        let mut o = Orchestrator::new(BusProfile::usb3_gen1(), 6);
        for i in 0..n {
            o.plug(SlotId(i as u8), Cartridge::new(0, kind, CapDescriptor::object_detect()))?;
        }
        let mut src = VideoSource::paper_stream(7);
        let rep = o.run_broadcast(&mut src, 60);
        println!("{:<10} {:>8.1} {:>11.1}% {:>11.1}% {:>14.2}",
            n, rep.fps, rep.wire_utilization * 100.0, rep.host_utilization * 100.0,
            rep.fps / n as f64);
    }
    Ok(())
}
