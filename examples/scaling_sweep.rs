//! Table-1 scaling sweep through the public API (paper §4.1), showing the
//! synchronous barrier baseline next to the event-driven batched engine.
//!
//!     cargo run --release --example scaling_sweep [-- coral] [-- batch4]

use champ::cli::bench::rack;
use champ::coordinator::engine::EngineConfig;
use champ::device::DeviceKind;
use champ::workload::video::VideoSource;

fn main() -> anyhow::Result<()> {
    let kind = if std::env::args().any(|a| a == "coral") {
        DeviceKind::Coral
    } else {
        DeviceKind::Ncs2
    };
    let batch = if std::env::args().any(|a| a == "batch4") { 4 } else { 1 };
    println!("broadcast scaling, {kind:?}, MobileNetV2 300x300, saturating stream, batch={batch}");
    println!("{:<8} {:>12} {:>12} {:>12} {:>10} {:>10}",
        "devices", "barrier FPS", "barrier agg", "engine agg", "wire util", "p99 ms");
    for n in 1..=5usize {
        let mut o = rack(kind, n)?;
        let mut src = VideoSource::paper_stream(7);
        let bar = o.run_broadcast(&mut src, 60);

        let mut o = rack(kind, n)?;
        let src = VideoSource::paper_stream(7);
        let cfg = EngineConfig::batched(batch).with_warmup(10);
        let eng = o.run_broadcast_engine(&src, 80, cfg, vec![]);

        println!("{:<8} {:>12.1} {:>12.1} {:>12.1} {:>9.1}% {:>10.1}",
            n, bar.fps, bar.fps * n as f64, eng.fps,
            eng.bus_utilization * 100.0, eng.latency.percentile_us(99.0) as f64 / 1e3);
    }
    println!("\nbarrier agg = device-completions/s under the per-frame barrier;");
    println!("engine agg  = the same quantity under event-driven batched dispatch.");
    Ok(())
}
