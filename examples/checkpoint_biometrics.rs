//! END-TO-END driver: the paper's Fig. 2 checkpoint-biometrics scenario,
//! with REAL compute at every stage (AOT-compiled HLO via PJRT — zero
//! Python on this path).
//!
//! Flow: synthetic camera frames -> RetinaFace-lite (face detect) ->
//! CR-FIQA-lite (quality gate) -> FaceNet-lite (128-d embedding) ->
//! storage cartridge holding a 1000-identity gallery protected by an
//! orthogonal-rotation key, matched with the secure_gallery_match HLO.
//! Mid-run the quality cartridge is hot-removed and re-inserted.
//!
//! Requires `make artifacts` first:
//!     cargo run --release --example checkpoint_biometrics
//!
//! Reports: rank-1 accuracy on planted identities, plaintext-vs-protected
//! score agreement, per-stage wall-clock, simulated FPS/latency, hot-swap
//! downtime, and the power envelope.  Recorded in EXPERIMENTS.md.

use std::time::Instant;

use champ::biometric::gallery::Gallery;
use champ::biometric::template::Template;
use champ::bus::topology::SlotId;
use champ::bus::usb3::BusProfile;
use champ::coordinator::scheduler::Orchestrator;
use champ::crypto::KeyChain;
use champ::device::caps::CapDescriptor;
use champ::device::storage::StorageCartridge;
use champ::device::{Backend, Cartridge, DeviceKind};
use champ::power::PowerModel;
use champ::runtime::{ExecutorPool, Manifest};
use champ::util::rng::Rng;
use champ::workload::traces::MissionTrace;
use champ::workload::video::VideoSource;

const GALLERY_IDS: usize = 1000;
const PROBES: usize = 40;
const DIM: usize = 128;

/// A synthetic "person": a base face image; probes add pixel noise.
fn face_pixels(rng: &mut Rng) -> Vec<f32> {
    (0..64 * 64 * 3).map(|_| rng.f32()).collect()
}

fn noisy(base: &[f32], rng: &mut Rng, sigma: f32) -> Vec<f32> {
    base.iter().map(|v| (v + sigma * rng.normal()).clamp(0.0, 1.0)).collect()
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts").map_err(|e| {
        anyhow::anyhow!("artifacts missing ({e}); run `make artifacts` first")
    })?;
    let pool = ExecutorPool::new(manifest)?;

    // ---- Stage executors (compile once — the model-load cost the
    //      hot-swap experiment pays is the simulated-time equivalent). ----
    let t0 = Instant::now();
    let detect = pool.get("retinaface_det")?;
    let quality = pool.get("crfiqa_quality")?;
    let embed = pool.get("facenet_embed")?;
    let secure_match = pool.get("secure_gallery_match")?;
    println!("compiled 4 artifacts in {:.1}s", t0.elapsed().as_secs_f64());

    // ---- Enroll the gallery: embed 1000 synthetic identities. ----------
    let mut rng = Rng::new(1234);
    let mut gallery = Gallery::new(DIM);
    let mut base_faces = Vec::with_capacity(GALLERY_IDS);
    let t0 = Instant::now();
    for i in 0..GALLERY_IDS {
        let face = face_pixels(&mut rng);
        let emb = embed.run_f32(&[face.clone()])?.remove(0);
        gallery.add(format!("subject-{i:04}"), Template::new(emb));
        base_faces.push(face);
    }
    println!("enrolled {GALLERY_IDS} identities in {:.1}s", t0.elapsed().as_secs_f64());

    // ---- Protect the gallery on the storage cartridge. ------------------
    let keys = KeyChain::derive("checkpoint-alpha", DIM);
    let storage = StorageCartridge::enroll(99, &gallery, keys.rotation, keys.seal);
    let rot_matrix = KeyChain::derive("checkpoint-alpha", DIM).rotation.to_hlo_matrix();
    // Rotated gallery matrix for the secure-match HLO (G=1024 capacity,
    // zero-padded — scores for empty rows are ~0 and never win).  The
    // bulk rotation rotates the whole SoA matrix in one pass.
    let rot_key = KeyChain::derive("checkpoint-alpha", DIM).rotation;
    let rot_index = rot_key.apply_index(gallery.index());
    let mut gal_rot = vec![0.0f32; 1024 * DIM];
    gal_rot[..rot_index.len() * DIM].copy_from_slice(rot_index.data());

    // ---- Probe loop: detect -> quality -> embed -> secure match. --------
    let mut rank1 = 0usize;
    let mut gated = 0usize;
    let mut score_diff_max = 0.0f32;
    let mut stage_ms = [0.0f64; 4];
    let mut batch_probes: Vec<Template> = Vec::with_capacity(PROBES);
    let mut batch_expect: Vec<String> = Vec::with_capacity(PROBES);
    for p in 0..PROBES {
        let true_id = p * (GALLERY_IDS / PROBES);
        let probe_face = noisy(&base_faces[true_id], &mut rng, 0.02);

        // Face detection on the full frame (96x96 synthetic scene that
        // contains the face crop statistics).
        let scene: Vec<f32> = (0..96 * 96 * 3).map(|_| rng.f32()).collect();
        let t = Instant::now();
        let det = detect.run_f32(&[scene])?;
        stage_ms[0] += t.elapsed().as_secs_f64() * 1e3;
        assert_eq!(det[0].len(), 36, "detector must emit 36 anchor scores");

        // Quality gate on the crop.
        let t = Instant::now();
        let q = quality.run_f32(&[probe_face.clone()])?[0][0];
        stage_ms[1] += t.elapsed().as_secs_f64() * 1e3;
        if q < 0.05 {
            gated += 1;
            continue;
        }

        // Embedding.
        let t = Instant::now();
        let emb = embed.run_f32(&[probe_face])?.remove(0);
        stage_ms[2] += t.elapsed().as_secs_f64() * 1e3;

        // Secure match on the storage cartridge (HLO path).
        let t = Instant::now();
        let out = secure_match.run_f32_refs(&[&emb, &rot_matrix, &gal_rot])?;
        stage_ms[3] += t.elapsed().as_secs_f64() * 1e3;
        let best_idx = out[1][0] as usize;
        let best_score = out[2][0];

        // Cross-check the HLO's decision against the rust-side protected
        // matcher (independent implementation, SoA index scan).
        let probe_t = Template::new(emb);
        let rust_out = storage.match_probe(&probe_t, 1).unwrap();
        let hlo_id = gallery.id_at(best_idx).unwrap_or("<pad>");
        score_diff_max = score_diff_max.max((rust_out.best_score - best_score).abs());
        assert_eq!(rust_out.best_id, hlo_id, "HLO and rust matchers disagree");
        batch_probes.push(probe_t);
        batch_expect.push(rust_out.best_id.clone());

        if hlo_id == format!("subject-{true_id:04}") {
            rank1 += 1;
        }
    }
    // Batched identification: one gallery pass for the whole probe set
    // (the path the dispatch engine uses to amortize a batch envelope).
    let t = Instant::now();
    let batched = storage.match_batch(&batch_probes, 1);
    let batch_ms = t.elapsed().as_secs_f64() * 1e3;
    for (out, expect) in batched.iter().zip(&batch_expect) {
        assert_eq!(
            out.as_ref().map(|o| o.best_id.as_str()),
            Some(expect.as_str()),
            "batched match must agree with per-probe match"
        );
    }
    println!(
        "batched match: {} probes in {batch_ms:.1} ms (one gallery pass, decisions identical)",
        batch_probes.len()
    );

    let attempted = PROBES - gated;
    println!("\n--- accuracy (real compute) ---");
    println!("rank-1: {rank1}/{attempted} ({:.1}%), quality-gated: {gated}",
        100.0 * rank1 as f64 / attempted.max(1) as f64);
    println!("max |plaintext-protected| score diff across matchers: {score_diff_max:.2e}");
    println!(
        "per-stage wall-clock mean: detect {:.1} ms, quality {:.1} ms, embed {:.1} ms, match {:.1} ms",
        stage_ms[0] / PROBES as f64, stage_ms[1] / PROBES as f64,
        stage_ms[2] / PROBES as f64, stage_ms[3] / PROBES as f64);
    assert!(rank1 as f64 / attempted.max(1) as f64 > 0.9, "rank-1 accuracy collapsed");

    // ---- Simulated deployment: timing + hot-swap over virtual time. -----
    let mut o = Orchestrator::new(BusProfile::usb3_gen1(), 6);
    o.plug(SlotId(0), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_detect())
        .with_backend(Backend::Real(detect.clone())))?;
    let q_uid = o.plug(SlotId(1), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_quality())
        .with_backend(Backend::Real(quality.clone())))?;
    o.plug(SlotId(2), Cartridge::new(0, DeviceKind::Ncs2, CapDescriptor::face_embed())
        .with_backend(Backend::Real(embed.clone())))?;

    let trace = MissionTrace::hotswap_experiment();
    let events = trace.to_hotplug_events(q_uid);
    let fps = 8.0;
    let frames = (trace.total_run_us() as f64 / 1e6 * fps) as u64;
    let mut cam = VideoSource::paper_stream(7).with_rate_fps(fps);
    let rep = o.run_pipelined(&mut cam, frames, events);

    println!("\n--- deployment (simulated bus/devices, 8 FPS source) ---");
    println!("frames: {} in / {} out / {} dropped | fps {:.2}",
        rep.frames_in, rep.frames_out, rep.frames_dropped, rep.fps);
    println!("latency: mean {:.1} ms (compute {:.1} ms, overhead {:.1}%)",
        rep.latency.mean_us() / 1e3, rep.compute_us_mean / 1e3,
        (rep.latency.mean_us() / rep.compute_us_mean - 1.0) * 100.0);
    for r in &rep.swap_records {
        println!("hot-swap {:?} slot {}: downtime {:.2} s ({:?})",
            r.kind, r.slot.0, r.downtime_us() as f64 / 1e6, r.action);
    }
    assert_eq!(rep.frames_dropped, 0);

    let pm = PowerModel::default();
    let power = pm.report(&o.device_busy(), rep.elapsed_us, rep.frames_out);
    println!("power: {:.1} W total ({:.1} W devices + {:.1} W host), {:.2} frames/J",
        power.total_w, power.device_w, power.host_w, power.frames_per_joule);
    println!("\ncheckpoint_biometrics OK");
    Ok(())
}
